"""AOT lowering: JAX graphs -> HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (invoked by `make artifacts`):

    cd python && python -m compile.aot --out ../artifacts

Emits one `filter_n{n}_k{k}_m{m}.hlo.txt` per compiled shape variant,
one `residual_n{n}_k{k}.hlo.txt`, and a `manifest.json` the rust
artifact registry reads.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import chebyshev as k_cheb  # noqa: E402

# Shape variants compiled by default. The XLA backend is the small-n
# composition path (DESIGN.md): n must match the densified operator the
# coordinator feeds it; k = L + guard of the compiled pipeline config.
DEFAULT_VARIANTS = [
    # (n, k, m)
    (256, 16, 20),
    (1024, 20, 20),
]

F64 = jnp.float64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_filter(n: int, k: int, m: int) -> str:
    """Lower the degree-m filter at shape (n, k) to HLO text."""
    tile = k_cheb.choose_tile(n, k)

    def fn(a, y0, target, c, e):
        return (
            model.chebyshev_filter(
                a, y0, target, c, e, degree=m, tile=tile, interpret=True
            ),
        )

    scalar = jax.ShapeDtypeStruct((), F64)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((n, n), F64),
        jax.ShapeDtypeStruct((n, k), F64),
        scalar,
        scalar,
        scalar,
    )
    return to_hlo_text(lowered)


def lower_residual(n: int, k: int) -> str:
    """Lower the residual-norm graph at shape (n, k) to HLO text."""

    def fn(a, v, lams):
        return (model.residual_norms(a, v, lams),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((n, n), F64),
        jax.ShapeDtypeStruct((n, k), F64),
        jax.ShapeDtypeStruct((k,), F64),
    )
    return to_hlo_text(lowered)


def build(out_dir: str, variants=None) -> dict:
    """Build all artifacts into `out_dir`; returns the manifest dict."""
    variants = variants or DEFAULT_VARIANTS
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for n, k, m in variants:
        name = f"filter_n{n}_k{k}_m{m}"
        path = f"{name}.hlo.txt"
        text = lower_filter(n, k, m)
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entries.append(
            {
                "kind": "filter",
                "name": name,
                "path": path,
                "n": n,
                "k": k,
                "m": m,
                "tile": k_cheb.choose_tile(n, k),
                "vmem_bytes": k_cheb.vmem_bytes(n, k, k_cheb.choose_tile(n, k)),
                "inputs": ["a[n,n]", "y0[n,k]", "target[]", "c[]", "e[]"],
                "dtype": "f64",
            }
        )
        rname = f"residual_n{n}_k{k}"
        rpath = f"{rname}.hlo.txt"
        with open(os.path.join(out_dir, rpath), "w") as f:
            f.write(lower_residual(n, k))
        entries.append(
            {
                "kind": "residual",
                "name": rname,
                "path": rpath,
                "n": n,
                "k": k,
                "inputs": ["a[n,n]", "v[n,k]", "lams[k]"],
                "dtype": "f64",
            }
        )
    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--variant",
        action="append",
        default=None,
        metavar="N,K,M",
        help="shape variant n,k,m (repeatable; default: built-in list)",
    )
    args = ap.parse_args()
    variants = None
    if args.variant:
        variants = [tuple(int(x) for x in v.split(",")) for v in args.variant]
    manifest = build(args.out, variants)
    total = len(manifest["artifacts"])
    print(f"wrote {total} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
