//! Davidson-type Jacobi–Davidson — the SLEPc JD stand-in.
//!
//! Block Davidson with the diagonal (Olsen-style) approximate solution of
//! the JD correction equation: for each targeted non-converged Ritz pair
//! the expansion vector is `t = (diag(A) − θ)⁻¹ r`, orthogonalized into
//! the search space; the space is restarted to the best Ritz vectors when
//! it exceeds `2(L+g)`. The paper's JD baseline (bcgsl inner solver)
//! belongs to the same family and shows the same profile: expensive per
//! iteration and hypersensitive to the initial-subspace dimension —
//! both effects reproduce here (Tables 1 and 2).

use super::{EigOptions, EigResult, SolveStats, WarmStart};
use crate::linalg::dense::{dot, norm2, vaxpy};
use crate::linalg::qr::householder_qr;
use crate::linalg::symeig::sym_eig;
use crate::linalg::{flops, Mat};
use crate::rng::Xoshiro256pp;
use crate::sparse::CsrMatrix;
use std::time::Instant;

/// Solve for the smallest `L` eigenpairs.
pub fn solve(a: &CsrMatrix, opts: &EigOptions, init: Option<&WarmStart>) -> EigResult {
    let t0 = Instant::now();
    flops::take();
    let n = a.rows();
    let l = opts.n_eigs;
    assert!(l >= 1 && l < n);
    let g = super::guard_size(l);
    let maxdim = (2 * (l + g) + 8).min(n - 1);
    let block = 8.min(l); // expansion vectors per outer iteration
    let tol = opts.tol;
    let diag = a.diagonal();
    let mut rng = Xoshiro256pp::seed_from_u64(opts.seed);
    let mut stats = SolveStats::default();

    // Initial search space. The default (paper: library default) starts
    // from a small random block; a warm start *replaces* it with the full
    // inherited subspace — exactly the Table 2 JD* modification that
    // changes the projected-problem dimension.
    let v0 = match init {
        Some(ws) => ws.vectors.clone(),
        None => Mat::randn(n, (l + g).min(maxdim), &mut rng),
    };
    let mut v = householder_qr(&v0);
    let mut best: Option<(Vec<f64>, Mat)> = None;

    while stats.iterations < opts.max_iters {
        stats.iterations += 1;
        // Rayleigh–Ritz on the search space.
        let av = a.spmm_alloc(&v);
        stats.matvecs += v.cols();
        let gm = v.t_matmul(&av);
        let eig = sym_eig(&gm);
        let want = l.min(eig.values.len());
        let u = v.matmul(&eig.vectors.cols_range(0, want.max(block).min(eig.values.len())));
        let theta = &eig.values;

        // Residuals of the wanted pairs.
        let au = a.spmm_alloc(&u);
        stats.matvecs += u.cols();
        let mut n_conv = 0;
        let mut residuals: Vec<Vec<f64>> = Vec::new();
        let mut rel: Vec<f64> = Vec::new();
        for j in 0..u.cols() {
            let mut r = vec![0.0f64; n];
            let mut an2 = 0.0;
            for i in 0..n {
                let avi = au[(i, j)];
                r[i] = avi - theta[j] * u[(i, j)];
                an2 += avi * avi;
            }
            flops::add(4 * n as u64);
            let rn = norm2(&r) / an2.sqrt().max(1e-300);
            rel.push(rn);
            residuals.push(r);
        }
        for j in 0..want {
            if rel[j] <= tol {
                n_conv += 1;
            } else {
                break;
            }
        }
        best = Some((theta[..want].to_vec(), u.cols_range(0, want)));
        if n_conv >= l {
            break;
        }

        // Restart *before* expanding (while `eig.vectors` still matches
        // the current space dimension): compress to the best Ritz block.
        if v.cols() + block > maxdim {
            let keep = (l + g).min(eig.vectors.cols());
            let compressed = v.matmul(&eig.vectors.cols_range(0, keep));
            v = householder_qr(&compressed);
        }

        // Expand with diagonally-preconditioned corrections for the first
        // `block` non-converged pairs.
        let mut added = 0;
        for j in n_conv..(n_conv + block).min(u.cols()) {
            if rel[j] <= tol {
                continue;
            }
            let mut t: Vec<f64> = (0..n)
                .map(|i| {
                    let mut d = diag[i] - theta[j];
                    let floor = 0.01 * diag[i].abs().max(1.0);
                    if d.abs() < floor {
                        d = if d >= 0.0 { floor } else { -floor };
                    }
                    residuals[j][i] / d
                })
                .collect();
            flops::add(3 * n as u64);
            // Orthogonalize into V (two passes).
            for _ in 0..2 {
                for c in 0..v.cols() {
                    let qc = v.col(c);
                    let coef = dot(&qc, &t);
                    vaxpy(-coef, &qc, &mut t);
                }
            }
            let nt = norm2(&t);
            if nt > 1e-10 {
                for x in &mut t {
                    *x /= nt;
                }
                let tm = Mat::from_vec(n, 1, t);
                v = v.hcat(&tm);
                added += 1;
            }
        }
        if added == 0 {
            // Stagnation: restart from the Ritz block with fresh noise.
            let noise = Mat::randn(n, 2.min(n - u.cols()), &mut rng);
            v = householder_qr(&u.hcat(&noise));
        }
    }

    stats.flops = flops::take();
    stats.secs = t0.elapsed().as_secs_f64();
    let (values, vectors) = best.expect("JD made no iterations");
    EigResult::finalize(a, values, vectors, stats, tol)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::{self, GenOptions, OperatorKind};

    fn problem(grid: usize, seed: u64) -> CsrMatrix {
        operators::generate(
            OperatorKind::Poisson,
            GenOptions {
                grid,
                ..Default::default()
            },
            1,
            seed,
        )
        .remove(0)
        .matrix
    }

    #[test]
    fn converges_on_small_poisson() {
        let a = problem(9, 1);
        let opts = EigOptions {
            n_eigs: 4,
            tol: 1e-8,
            max_iters: 800,
            seed: 0,
        };
        let r = solve(&a, &opts, None);
        assert!(r.stats.converged, "{:?}", r.residuals);
        let want = sym_eig(&a.to_dense());
        for (got, want) in r.values.iter().zip(&want.values[..4]) {
            assert!((got - want).abs() / want < 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn warm_start_changes_subspace_dimension() {
        // JD* (Table 2): the inherited init replaces the default small
        // block — correctness must hold either way.
        let a = problem(9, 2);
        let opts = EigOptions {
            n_eigs: 4,
            tol: 1e-8,
            max_iters: 800,
            seed: 1,
        };
        let cold = solve(&a, &opts, None);
        let warm = solve(&a, &opts, Some(&cold.as_warm_start()));
        assert!(warm.stats.converged);
        for (w, c) in warm.values.iter().zip(&cold.values) {
            assert!((w - c).abs() / c.abs().max(1.0) < 1e-6);
        }
    }

    #[test]
    fn is_slower_than_lanczos() {
        // The paper's JD column loses by a wide margin; at minimum ours
        // must not beat Lanczos in matvec count on a stiff problem.
        let a = problem(11, 3);
        let opts = EigOptions {
            n_eigs: 6,
            tol: 1e-8,
            max_iters: 2000,
            seed: 2,
        };
        let jd = solve(&a, &opts, None);
        let lz = super::super::lanczos::solve(&a, &opts, None);
        assert!(jd.stats.matvecs >= lz.stats.matvecs / 4);
    }
}
