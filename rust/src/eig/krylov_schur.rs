//! Krylov–Schur for Hermitian matrices — the SLEPc KS stand-in.
//!
//! For symmetric problems the Krylov–Schur restart (Stewart 2002) is the
//! thick-restart Lanczos recurrence with a leaner subspace policy: the
//! Schur (here: spectral) decomposition of the projected matrix is
//! truncated to the wanted block plus a small buffer, and expansion
//! resumes from the residual. We therefore share the engine in
//! [`super::lanczos`] and differ in the restart geometry — SLEPc's
//! default `mpd`-style sizing — which produces the distinct convergence
//! profile visible in the reproduced Table 1.

use super::op::SpectralOp;
use super::solver::Workspace;
use super::{EigOptions, EigResult, WarmStart};
use crate::sparse::CsrMatrix;

/// Solve with Krylov–Schur subspace sizing:
/// `m = min(n−1, L + g + max(8, (L+g)/2))`, keeping `L + g/2` pairs.
pub fn solve(a: &CsrMatrix, opts: &EigOptions, init: Option<&WarmStart>) -> EigResult {
    let mut ws = Workspace::new(1);
    solve_in(a, opts, init, &mut ws)
}

/// [`solve`] inside a caller-owned, reusable [`Workspace`].
pub fn solve_in(
    a: &CsrMatrix,
    opts: &EigOptions,
    init: Option<&WarmStart>,
    ws: &mut Workspace,
) -> EigResult {
    solve_op_in(&SpectralOp::standard(a), opts, init, ws)
}

/// [`solve_in`] on an abstract [`SpectralOp`] (plain, generalized or
/// shift-inverted); bit-for-bit the historical path for plain operators.
pub fn solve_op_in(
    op: &SpectralOp,
    opts: &EigOptions,
    init: Option<&WarmStart>,
    ws: &mut Workspace,
) -> EigResult {
    let l = opts.n_eigs;
    let g = super::guard_size(l);
    let keep = l + (g / 2).max(2);
    let m = (l + g + ((l + g) / 2).max(8)).min(op.n() - 1);
    super::lanczos::thick_restart_engine(op, opts, init, m, keep, ws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::symeig::sym_eig;
    use crate::operators::{self, GenOptions, OperatorKind};

    fn problem(grid: usize, seed: u64) -> CsrMatrix {
        operators::generate(
            OperatorKind::Helmholtz,
            GenOptions {
                grid,
                ..Default::default()
            },
            1,
            seed,
        )
        .remove(0)
        .matrix
    }

    #[test]
    fn converges_and_matches_dense_reference() {
        let a = problem(10, 1);
        let opts = EigOptions {
            n_eigs: 6,
            tol: 1e-9,
            max_iters: 500,
            seed: 0,
        };
        let r = solve(&a, &opts, None);
        assert!(r.stats.converged);
        let want = sym_eig(&a.to_dense());
        for (got, want) in r.values.iter().zip(&want.values[..6]) {
            assert!((got - want).abs() / want < 1e-7);
        }
    }

    #[test]
    fn uses_smaller_subspace_than_eigsh() {
        // The KS policy restarts more (leaner subspace): compare restart
        // cycle counts on the same problem.
        let a = problem(12, 2);
        let opts = EigOptions {
            n_eigs: 8,
            tol: 1e-8,
            max_iters: 500,
            seed: 1,
        };
        let ks = solve(&a, &opts, None);
        let ar = super::super::lanczos::solve(&a, &opts, None);
        assert!(ks.stats.converged && ar.stats.converged);
        assert!(
            ks.stats.iterations >= ar.stats.iterations,
            "ks {} vs eigsh {}",
            ks.stats.iterations,
            ar.stats.iterations
        );
    }

    #[test]
    fn warm_start_accepted() {
        let a = problem(9, 3);
        let opts = EigOptions {
            n_eigs: 4,
            tol: 1e-8,
            max_iters: 500,
            seed: 2,
        };
        let cold = solve(&a, &opts, None);
        let warm = solve(&a, &opts, Some(&cold.as_warm_start()));
        assert!(warm.stats.converged);
    }
}
