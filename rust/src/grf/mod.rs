//! Gaussian random fields (GRF) on a periodic 2-D grid, sampled spectrally.
//!
//! The paper's datasets (§D.2) draw every operator's coefficient fields —
//! `K(x,y)` for the generalized Poisson operator, `p, k` for Helmholtz,
//! `D, ρ` for the vibration plate — from a GRF. We use the standard
//! Matérn-like spectral density
//!
//! ```text
//! S(k) ∝ (|k|² + τ²)^(−α)
//! ```
//!
//! (the same family as the FNO benchmark generators, Li et al. 2020):
//! white noise is sampled in the frequency domain, shaped by √S, and
//! transformed back. Larger `α`/smaller `τ` → smoother fields → more
//! low-frequency energy — exactly the property the truncated-FFT sorting
//! relies on (paper Appendix F / Table 20).

use crate::fft::{fft2_inplace, C64};
use crate::rng::Xoshiro256pp;

/// Parameters of the Matérn-like spectral density.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GrfParams {
    /// Spectral decay exponent (smoothness); paper-style fields use 2–3.
    pub alpha: f64,
    /// Inverse length scale.
    pub tau: f64,
}

impl Default for GrfParams {
    fn default() -> Self {
        Self {
            alpha: 2.5,
            tau: 3.0,
        }
    }
}

/// Sample a zero-mean GRF on a `p × p` grid (row-major), unit-ish variance.
pub fn sample(p: usize, params: GrfParams, rng: &mut Xoshiro256pp) -> Vec<f64> {
    assert!(p >= 2);
    // Hermitian-symmetric white noise is implicit: we fill complex noise
    // and keep the real part of the inverse transform; this halves the
    // variance but preserves the GRF law up to scale, which the
    // normalization below absorbs.
    let mut spec = vec![C64::zero(); p * p];
    for (t, z) in spec.iter_mut().enumerate() {
        let (r, c) = (t / p, t % p);
        // Wrapped integer frequencies in [-p/2, p/2).
        let kr = if r <= p / 2 { r as f64 } else { r as f64 - p as f64 };
        let kc = if c <= p / 2 { c as f64 } else { c as f64 - p as f64 };
        let k2 = kr * kr + kc * kc;
        let amp = (k2 + params.tau * params.tau).powf(-params.alpha / 2.0);
        let (g1, g2) = rng.normal_pair();
        *z = C64::new(g1 * amp, g2 * amp);
    }
    // Kill the mean mode so fields are zero-mean.
    spec[0] = C64::zero();
    fft2_inplace(&mut spec, p, true);
    let field: Vec<f64> = spec.iter().map(|z| z.re).collect();
    // Normalize to unit sample std so downstream transforms are stable.
    let n = (p * p) as f64;
    let mean: f64 = field.iter().sum::<f64>() / n;
    let var: f64 = field.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    let std = var.sqrt().max(1e-300);
    field.into_iter().map(|x| (x - mean) / std).collect()
}

/// Sample a *positive* coefficient field: affine-transformed GRF
/// `lo + (hi − lo) · sigmoid(g)`, guaranteed in `(lo, hi)`. This is how
/// diffusion/rigidity coefficients (`K`, `p`, `D`, `ρ`) are produced.
pub fn sample_positive(
    p: usize,
    params: GrfParams,
    lo: f64,
    hi: f64,
    rng: &mut Xoshiro256pp,
) -> Vec<f64> {
    assert!(lo > 0.0 && hi > lo);
    sample(p, params, rng)
        .into_iter()
        .map(|g| lo + (hi - lo) / (1.0 + (-g).exp()))
        .collect()
}

/// A *perturbed copy* of a base field: `base + eps · fresh GRF`, then
/// re-clamped to `(lo, hi)`. Used by the similarity experiment
/// (paper Table 17) where each problem is a controlled perturbation of
/// the previous one.
pub fn perturb(
    base: &[f64],
    p: usize,
    params: GrfParams,
    eps: f64,
    lo: f64,
    hi: f64,
    rng: &mut Xoshiro256pp,
) -> Vec<f64> {
    assert_eq!(base.len(), p * p);
    let noise = sample(p, params, rng);
    base.iter()
        .zip(&noise)
        .map(|(b, n)| {
            let scale = (hi - lo) * 0.25; // noise amplitude relative to range
            (b + eps * scale * n).clamp(lo, hi)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fft2_real, spec_energy, truncate_low_freq};

    #[test]
    fn deterministic_per_seed() {
        let mut r1 = Xoshiro256pp::seed_from_u64(5);
        let mut r2 = Xoshiro256pp::seed_from_u64(5);
        let a = sample(32, GrfParams::default(), &mut r1);
        let b = sample(32, GrfParams::default(), &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn normalized_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let f = sample(64, GrfParams::default(), &mut rng);
        let n = f.len() as f64;
        let mean: f64 = f.iter().sum::<f64>() / n;
        let var: f64 = f.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        assert!(mean.abs() < 1e-10);
        assert!((var - 1.0).abs() < 1e-10);
    }

    #[test]
    fn energy_is_concentrated_in_low_frequencies() {
        // This is the property Table 20 reports: >95 % of energy below
        // frequency p0 = 20 (we use a smaller grid, same shape).
        let p = 64;
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let f = sample(p, GrfParams::default(), &mut rng);
        let spec = fft2_real(&f, p);
        let low = truncate_low_freq(&spec, p, 20);
        let ratio = spec_energy(&low) / spec_energy(&spec);
        assert!(ratio > 0.95, "low-frequency ratio {ratio}");
    }

    #[test]
    fn positive_fields_respect_bounds() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let f = sample_positive(32, GrfParams::default(), 0.5, 2.0, &mut rng);
        assert!(f.iter().all(|&x| x > 0.5 && x < 2.0));
    }

    #[test]
    fn smoother_params_give_more_lowfreq_energy() {
        let p = 64;
        let ratio_for = |alpha: f64, seed: u64| {
            let mut rng = Xoshiro256pp::seed_from_u64(seed);
            let f = sample(p, GrfParams { alpha, tau: 3.0 }, &mut rng);
            let spec = fft2_real(&f, p);
            spec_energy(&truncate_low_freq(&spec, p, 8)) / spec_energy(&spec)
        };
        // Average over a few seeds to avoid single-sample flukes.
        let rough: f64 = (0..5).map(|s| ratio_for(1.0, s)).sum::<f64>() / 5.0;
        let smooth: f64 = (0..5).map(|s| ratio_for(4.0, s)).sum::<f64>() / 5.0;
        assert!(smooth > rough, "smooth {smooth} vs rough {rough}");
    }

    #[test]
    fn perturb_scales_with_eps() {
        let p = 32;
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let base = sample_positive(p, GrfParams::default(), 0.5, 2.0, &mut rng);
        let d = |eps: f64, seed: u64| {
            let mut r = Xoshiro256pp::seed_from_u64(seed);
            let pert = perturb(&base, p, GrfParams::default(), eps, 0.5, 2.0, &mut r);
            base.iter()
                .zip(&pert)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt()
        };
        assert_eq!(d(0.0, 9), 0.0);
        assert!(d(0.01, 9) < d(0.1, 9));
        assert!(d(0.1, 9) < d(0.5, 9));
    }
}
