//! Locally Optimal Block Preconditioned Conjugate Gradient (Knyazev 2001)
//! — the SLEPc LOBPCG stand-in, with a clamped Jacobi preconditioner.
//!
//! The robust "basis" formulation: each iteration performs Rayleigh–Ritz
//! on the orthonormalized frame `S = [X | W | P]` (iterate, preconditioned
//! residual, conjugate direction) and extracts the new iterate and the
//! implicit CG direction from the Ritz coefficients.

use super::{EigOptions, EigResult, SolveStats, WarmStart};
use crate::linalg::qr::householder_qr;
use crate::linalg::symeig::sym_eig;
use crate::linalg::{flops, Mat};
use crate::rng::Xoshiro256pp;
use crate::sparse::CsrMatrix;
use std::time::Instant;

/// Solve for the smallest `L` eigenpairs.
pub fn solve(a: &CsrMatrix, opts: &EigOptions, init: Option<&WarmStart>) -> EigResult {
    let t0 = Instant::now();
    flops::take();
    let n = a.rows();
    let l = opts.n_eigs;
    assert!(l >= 1 && l < n);
    // Block size: wanted + guard, but the 3k-column frame must fit in n.
    let k = (l + super::guard_size(l)).min((n - 1) / 3).max(l);
    assert!(
        3 * k <= n,
        "LOBPCG frame does not fit: need 3(L+g) ≤ n (L={l}, n={n})"
    );
    let tol = opts.tol;
    let diag = a.diagonal();
    let mut rng = Xoshiro256pp::seed_from_u64(opts.seed);
    let mut stats = SolveStats::default();

    // Initial block.
    let x0 = match init {
        Some(ws) => {
            let have = ws.vectors.cols().min(k);
            let inh = ws.vectors.cols_range(0, have);
            if have < k {
                inh.hcat(&Mat::randn(n, k - have, &mut rng))
            } else {
                inh
            }
        }
        None => Mat::randn(n, k, &mut rng),
    };
    let mut x = householder_qr(&x0);
    let mut p: Option<Mat> = None;
    let mut theta = vec![0.0f64; k];
    let mut best: Option<(Vec<f64>, Mat)> = None;

    while stats.iterations < opts.max_iters {
        stats.iterations += 1;
        let ax = a.spmm_alloc(&x);
        stats.matvecs += x.cols();
        // Rayleigh quotients per column (X has orthonormal columns).
        for j in 0..k {
            let mut t = 0.0;
            for i in 0..n {
                t += x[(i, j)] * ax[(i, j)];
            }
            theta[j] = t;
        }
        flops::add(2 * (n * k) as u64);
        // Residuals R = AX − XΘ and relative norms.
        let mut r = ax.clone();
        for i in 0..n {
            let rrow = r.row_mut(i);
            let xrow = x.row(i);
            for j in 0..k {
                rrow[j] -= theta[j] * xrow[j];
            }
        }
        flops::add(2 * (n * k) as u64);
        let mut n_conv = 0;
        for j in 0..l {
            let rn = r.col_norm(j);
            let an = ax.col_norm(j).max(1e-300);
            if rn / an <= tol {
                n_conv += 1;
            } else {
                break;
            }
        }
        best = Some((theta[..l].to_vec(), x.cols_range(0, l)));
        if n_conv >= l {
            break;
        }

        // Preconditioned residual W: clamped Jacobi (diag(A) − θ_j)⁻¹ r.
        let mut w = Mat::zeros(n, k);
        for i in 0..n {
            let wrow = w.row_mut(i);
            let rrow = r.row(i);
            for j in 0..k {
                let mut d = diag[i] - theta[j];
                let floor = 0.01 * diag[i].abs().max(1.0);
                if d.abs() < floor {
                    d = if d >= 0.0 { floor } else { -floor };
                }
                wrow[j] = rrow[j] / d;
            }
        }
        flops::add(3 * (n * k) as u64);

        // Frame S = [X | W | P], orthonormalized.
        let s_raw = match &p {
            Some(pm) => x.hcat(&w).hcat(pm),
            None => x.hcat(&w),
        };
        let s = householder_qr(&s_raw);
        // Rayleigh–Ritz on the frame.
        let as_ = a.spmm_alloc(&s);
        stats.matvecs += s.cols();
        let g = s.t_matmul(&as_);
        let eig = sym_eig(&g);
        let c = eig.vectors.cols_range(0, k);
        let x_new = s.matmul(&c);
        // Implicit conjugate direction: the W/P contribution only.
        let mut c_p = c.clone();
        for i in 0..k {
            for j in 0..k {
                c_p[(i, j)] = 0.0;
            }
        }
        let mut p_new = s.matmul(&c_p);
        // Normalize direction columns (guard against collapse).
        for j in 0..k {
            let nn = p_new.col_norm(j);
            if nn > 1e-12 {
                for i in 0..n {
                    p_new[(i, j)] /= nn;
                }
            }
        }
        x = x_new;
        p = Some(p_new);
        theta.copy_from_slice(&eig.values[..k]);
    }

    stats.flops = flops::take();
    stats.secs = t0.elapsed().as_secs_f64();
    let (values, vectors) = best.expect("LOBPCG made no iterations");
    EigResult::finalize(a, values, vectors, stats, tol)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::{self, GenOptions, OperatorKind};

    fn problem(kind: OperatorKind, grid: usize, seed: u64) -> CsrMatrix {
        operators::generate(
            kind,
            GenOptions {
                grid,
                ..Default::default()
            },
            1,
            seed,
        )
        .remove(0)
        .matrix
    }

    #[test]
    fn converges_on_poisson() {
        let a = problem(OperatorKind::Poisson, 10, 1);
        let opts = EigOptions {
            n_eigs: 6,
            tol: 1e-8,
            max_iters: 600,
            seed: 0,
        };
        let r = solve(&a, &opts, None);
        assert!(r.stats.converged, "{:?}", r.residuals);
        let want = sym_eig(&a.to_dense());
        for (got, want) in r.values.iter().zip(&want.values[..6]) {
            assert!((got - want).abs() / want < 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn converges_on_helmholtz() {
        let a = problem(OperatorKind::Helmholtz, 9, 2);
        let opts = EigOptions {
            n_eigs: 5,
            tol: 1e-8,
            max_iters: 600,
            seed: 1,
        };
        let r = solve(&a, &opts, None);
        assert!(r.stats.converged);
    }

    #[test]
    fn warm_start_speeds_convergence() {
        // Table 2: LOBPCG* accelerates significantly — subspace-based
        // logic benefits from a good initial block.
        let a = problem(OperatorKind::Helmholtz, 11, 3);
        let opts = EigOptions {
            n_eigs: 6,
            tol: 1e-8,
            max_iters: 800,
            seed: 2,
        };
        let cold = solve(&a, &opts, None);
        let warm = solve(&a, &opts, Some(&cold.as_warm_start()));
        assert!(warm.stats.converged);
        assert!(
            warm.stats.iterations < cold.stats.iterations,
            "warm {} cold {}",
            warm.stats.iterations,
            cold.stats.iterations
        );
    }

    #[test]
    fn values_ascend() {
        let a = problem(OperatorKind::Elliptic, 9, 4);
        let opts = EigOptions {
            n_eigs: 5,
            tol: 1e-7,
            max_iters: 600,
            seed: 3,
        };
        let r = solve(&a, &opts, None);
        for w in r.values.windows(2) {
            assert!(w[1] >= w[0] - 1e-10);
        }
    }
}
