//! Bench: paper Tables 11–14 — component breakdown and hyperparameter
//! sweeps (degree m, inherited-subspace size, truncation p0).
use scsf::bench_support::{tables, Scale};

fn main() {
    let scale = Scale::quick();
    tables::table11(&scale).print();
    println!();
    tables::table12(&scale, &[12, 16, 20, 24, 28, 32, 36, 40]).print();
    println!();
    let l = *scale.ls.last().unwrap();
    let guards: Vec<usize> = (1..=6).map(|i| i * l / 8 + 1).collect();
    tables::table13(&scale, &guards).print();
    println!();
    tables::table14(&scale, &[2, 4, scale.p0, scale.p0 * 2]).print();
}
