//! Subspace-similarity metrics — the paper's "one-sided distance"
//! (Table 14): principal angles between the leading invariant subspaces
//! of two problems. Used to *evaluate* sort quality, not to sort (it
//! needs the eigenvectors, which is what we are trying to avoid
//! computing).

use crate::linalg::symeig::sym_eig;
use crate::linalg::Mat;

/// One-sided subspace distance between two orthonormal bases `U, V`
/// (n × k): the RMS sine of the principal angles,
///
/// ```text
/// d(U, V) = sqrt(1 − mean_i σ_i²),   σ_i = singular values of UᵀV.
/// ```
///
/// 0 = identical subspaces, 1 = orthogonal. Smaller means more similar
/// (the convention of paper Table 14).
pub fn one_sided_distance(u: &Mat, v: &Mat) -> f64 {
    assert_eq!(u.rows(), v.rows());
    assert_eq!(u.cols(), v.cols(), "subspace dimensions must match");
    let k = u.cols();
    if k == 0 {
        return 0.0;
    }
    // σ_i² are the eigenvalues of (UᵀV)ᵀ(UᵀV).
    let m = u.t_matmul(v);
    let mtm = m.t_matmul(&m);
    let eig = sym_eig(&mtm);
    let mean_sq: f64 = eig.values.iter().map(|s| s.clamp(0.0, 1.0)).sum::<f64>() / k as f64;
    (1.0 - mean_sq).max(0.0).sqrt()
}

/// Average one-sided distance between *adjacent* problems of a solve
/// order, measured on their `dim`-dimensional leading invariant
/// subspaces (computed densely — evaluation only, small problems).
pub fn adjacent_subspace_distance(
    matrices: &[crate::sparse::CsrMatrix],
    order: &[usize],
    dim: usize,
) -> f64 {
    assert!(order.len() >= 2);
    let bases: Vec<Mat> = order
        .iter()
        .map(|&i| {
            let eig = sym_eig(&matrices[i].to_dense());
            eig.vectors.cols_range(0, dim.min(eig.vectors.cols()))
        })
        .collect();
    let mut total = 0.0;
    for w in bases.windows(2) {
        total += one_sided_distance(&w[0], &w[1]);
    }
    total / (order.len() - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::householder_qr;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn identical_subspace_has_zero_distance() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let q = householder_qr(&Mat::randn(30, 5, &mut rng));
        assert!(one_sided_distance(&q, &q) < 1e-7);
    }

    #[test]
    fn rotation_within_subspace_is_free() {
        // Same span, different basis: distance 0.
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let q = householder_qr(&Mat::randn(30, 4, &mut rng));
        let rot = householder_qr(&Mat::randn(4, 4, &mut rng));
        let q2 = q.matmul(&rot);
        assert!(one_sided_distance(&q, &q2) < 1e-7);
    }

    #[test]
    fn orthogonal_subspaces_have_distance_one() {
        let n = 20;
        let u = Mat::from_fn(n, 3, |i, j| if i == j { 1.0 } else { 0.0 });
        let v = Mat::from_fn(n, 3, |i, j| if i == j + 10 { 1.0 } else { 0.0 });
        assert!((one_sided_distance(&u, &v) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn distance_is_symmetric_and_bounded() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let u = householder_qr(&Mat::randn(25, 4, &mut rng));
        let v = householder_qr(&Mat::randn(25, 4, &mut rng));
        let duv = one_sided_distance(&u, &v);
        let dvu = one_sided_distance(&v, &u);
        assert!((duv - dvu).abs() < 1e-10);
        assert!((0.0..=1.0).contains(&duv));
    }

    #[test]
    fn similar_operators_have_small_adjacent_distance() {
        use crate::operators::{helmholtz, GenOptions};
        let opts = GenOptions {
            grid: 8,
            ..Default::default()
        };
        let chain = helmholtz::generate_perturbed_chain(opts, 3, 0.02, 1);
        let mats: Vec<_> = chain.into_iter().map(|p| p.matrix).collect();
        let d_close = adjacent_subspace_distance(&mats, &[0, 1, 2], 5);
        // Independent problems for contrast.
        let far = crate::operators::generate(
            crate::operators::OperatorKind::Helmholtz,
            opts,
            3,
            99,
        );
        let far_mats: Vec<_> = far.into_iter().map(|p| p.matrix).collect();
        let d_far = adjacent_subspace_distance(&far_mats, &[0, 1, 2], 5);
        assert!(d_close < d_far, "close {d_close} far {d_far}");
    }
}
