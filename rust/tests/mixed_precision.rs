//! Integration tests for the mixed-precision filter path and the
//! SELL-C-σ backend (ISSUE 6): accuracy across every operator family
//! under `precision: mixed` on both sparse layouts, the byte-for-bit
//! default regression, and the manifest echo of knobs and counters.

use scsf::coordinator::config::GenConfig;
use scsf::coordinator::dataset::DatasetReader;
use scsf::coordinator::pipeline::generate_dataset;
use scsf::eig::chebyshev::{FilterBackendKind, Precision};
use scsf::eig::chfsi::ChfsiOptions;
use scsf::eig::scsf::{solve_sequence, ScsfOptions, SequenceResult};
use scsf::eig::EigOptions;
use scsf::linalg::symeig::sym_eig;
use scsf::operators::{self, FamilyRegistry, GenOptions, OperatorKind, Problem};
use scsf::sort::SortMethod;
use scsf::util::json::Value;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("scsf_mixed_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn sequence(
    problems: &[Problem],
    l: usize,
    tol: f64,
    precision: Precision,
    backend: FilterBackendKind,
) -> SequenceResult {
    let mut chfsi = ChfsiOptions::from_eig(&EigOptions {
        n_eigs: l,
        tol,
        max_iters: 600,
        seed: 0,
    });
    chfsi.precision = precision;
    chfsi.filter_backend = backend;
    solve_sequence(
        problems,
        &ScsfOptions {
            chfsi,
            sort: SortMethod::TruncatedFft { p0: 6 },
            warm_start: true,
        },
    )
}

/// Property: across all five built-in families and both sparse
/// layouts, `precision: mixed` returns every wanted residual ≤ tol
/// and matches the dense reference eigenvalues — the knob trades
/// kernel bandwidth, never accuracy. Mixed runs must also actually
/// route filter sweeps through the f32 kernels.
#[test]
fn mixed_precision_meets_tolerance_across_all_families() {
    for kind in OperatorKind::ALL {
        let tol = kind.default_tol();
        let problems = operators::generate(
            kind,
            GenOptions {
                grid: 10,
                ..Default::default()
            },
            3,
            29,
        );
        let l = 5;
        for backend in [FilterBackendKind::Csr, FilterBackendKind::Sell] {
            let seq = sequence(&problems, l, tol, Precision::Mixed, backend);
            assert!(
                seq.all_converged(),
                "{kind:?}/{} did not converge",
                backend.name()
            );
            assert!(
                seq.f32_matvecs() > 0,
                "{kind:?}/{}: mixed precision ran no f32 filter work",
                backend.name()
            );
            assert!(
                seq.f32_matvecs() <= seq.filter_matvecs(),
                "{kind:?}/{}: more f32 matvecs than filter matvecs",
                backend.name()
            );
            for (pos, &pid) in seq.order.iter().enumerate() {
                let r = &seq.results[pos];
                for res in &r.residuals {
                    assert!(
                        *res <= tol,
                        "{kind:?}/{} problem {pid}: residual {res} > {tol}",
                        backend.name()
                    );
                }
                let want = sym_eig(&problems[pid].matrix.to_dense());
                for (got, w) in r.values.iter().zip(&want.values[..l]) {
                    assert!(
                        (got - w).abs() / w.abs().max(1.0) < 1e-6,
                        "{kind:?}/{} problem {pid}: {got} vs {w}",
                        backend.name()
                    );
                }
            }
        }
    }
}

/// The SELL backend at full f64 precision is a pure layout change: it
/// must converge to the same tolerances with zero f32 work, on every
/// family.
#[test]
fn sell_layout_is_accuracy_neutral_in_f64() {
    for kind in OperatorKind::ALL {
        let tol = kind.default_tol();
        let problems = operators::generate(
            kind,
            GenOptions {
                grid: 10,
                ..Default::default()
            },
            2,
            31,
        );
        let seq = sequence(&problems, 5, tol, Precision::F64, FilterBackendKind::Sell);
        assert!(seq.all_converged(), "{kind:?} did not converge under sell");
        assert_eq!(seq.f32_matvecs(), 0, "{kind:?}: f64 run counted f32 work");
        for r in &seq.results {
            for res in &r.residuals {
                assert!(*res <= tol, "{kind:?}: residual {res} > {tol}");
            }
        }
    }
}

/// Bit-for-bit regression: a config that never mentions `precision`
/// or `filter_backend` and one that pins the defaults (`"f64"`,
/// `"csr"`) must produce byte-identical `eigs.bin` files and
/// identical manifest record indexes — the knobs' compatibility
/// contract at the pipeline level.
#[test]
fn default_precision_reproduces_legacy_dataset_exactly() {
    let d_legacy = tmpdir("legacy");
    let d_explicit = tmpdir("explicit");
    // A config JSON with neither new key (the historical form).
    let legacy_json = r#"{
        "families": [{"family": "helmholtz", "count": 5}],
        "grid": 8, "n_eigs": 4, "tol": 1e-8, "seed": 11,
        "shards": 2, "channel_capacity": 2,
        "sort": {"method": "truncated_fft", "p0": 6}
    }"#;
    let cfg_legacy = GenConfig::from_json(legacy_json).unwrap();
    assert_eq!(cfg_legacy.precision, Precision::F64);
    assert_eq!(cfg_legacy.filter_backend, FilterBackendKind::Csr);
    let explicit_json = legacy_json.replace(
        "\"grid\": 8,",
        "\"grid\": 8, \"precision\": \"f64\", \"filter_backend\": \"csr\",",
    );
    let cfg_explicit = GenConfig::from_json(&explicit_json).unwrap();
    assert_eq!(cfg_explicit.precision, Precision::F64);
    assert_eq!(cfg_explicit.filter_backend, FilterBackendKind::Csr);

    generate_dataset(&cfg_legacy, &d_legacy).unwrap();
    generate_dataset(&cfg_explicit, &d_explicit).unwrap();
    let bin1 = std::fs::read(d_legacy.join("eigs.bin")).unwrap();
    let bin2 = std::fs::read(d_explicit.join("eigs.bin")).unwrap();
    assert_eq!(bin1, bin2, "eigs.bin must be byte-identical");
    let r1 = DatasetReader::open(&d_legacy).unwrap();
    let r2 = DatasetReader::open(&d_explicit).unwrap();
    assert_eq!(r1.index(), r2.index(), "manifest record indexes differ");
    let _ = std::fs::remove_dir_all(&d_legacy);
    let _ = std::fs::remove_dir_all(&d_explicit);
}

/// End-to-end mixed-precision dataset on the SELL layout: converges
/// at tolerance, echoes both knobs in the manifest config, and rolls
/// the f32 matvec / promotion counters up from per-record index
/// entries to the report totals.
#[test]
fn mixed_sell_dataset_end_to_end() {
    let dir = tmpdir("e2e");
    let mut cfg = GenConfig::from_json(
        r#"{
        "families": [{"family": "poisson", "count": 4}],
        "grid": 8, "n_eigs": 4, "tol": 1e-8, "seed": 3,
        "shards": 2, "precision": "mixed", "filter_backend": "sell",
        "sort": {"method": "truncated_fft", "p0": 6}
    }"#,
    )
    .unwrap();
    cfg.channel_capacity = 2;
    let report = generate_dataset(&cfg, &dir).unwrap();
    assert!(report.all_converged);
    assert!(report.max_residual <= 1e-8 * 10.0);
    assert!(report.f32_matvecs > 0, "no f32 filter work recorded");
    assert!(report.f32_matvecs <= report.filter_matvecs);
    let manifest = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    let v = scsf::util::json::parse(&manifest).unwrap();
    let cfg_echo = v.get("config").unwrap();
    assert_eq!(
        cfg_echo.get("precision").and_then(Value::as_str),
        Some("mixed")
    );
    assert_eq!(
        cfg_echo.get("filter_backend").and_then(Value::as_str),
        Some("sell")
    );
    // The report echo carries the totals, and the per-record index
    // entries sum back up to them.
    let rep = v.get("report").unwrap();
    assert_eq!(
        rep.get("f32_matvecs").and_then(Value::as_f64),
        Some(report.f32_matvecs as f64)
    );
    let reader = DatasetReader::open(&dir).unwrap();
    let rec_f32: usize = reader.index().iter().map(|r| r.f32_matvecs).sum();
    let rec_promotions: usize = reader.index().iter().map(|r| r.promotions).sum();
    assert_eq!(rec_f32, report.f32_matvecs, "per-record f32 sum != total");
    assert_eq!(rec_promotions, report.promotions, "promotion sum != total");
    // Values still match dense references.
    let problems = scsf::coordinator::pipeline::generate_problems(&cfg);
    let mut reader = DatasetReader::open(&dir).unwrap();
    for p in &problems {
        let rec = reader.read(p.id).unwrap();
        let want = sym_eig(&p.matrix.to_dense());
        for (got, w) in rec.values.iter().zip(&want.values[..4]) {
            assert!((got - w).abs() / w.abs().max(1.0) < 1e-6, "problem {}", p.id);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The knobs are rejected everywhere the XLA backend could see them:
/// config resolution fails before any pipeline work happens.
#[test]
fn xla_backend_rejects_knobs_at_config_resolution() {
    let reg = FamilyRegistry::builtin();
    let base = r#"{
        "families": [{"family": "helmholtz", "count": 2}],
        "grid": 8, "n_eigs": 4, "tol": 1e-8, "seed": 1,
        "backend": {"kind": "xla", "artifacts_dir": "/nonexistent"},
        "sort": {"method": "truncated_fft", "p0": 6}
    }"#;
    let mixed = base.replace("\"grid\": 8,", "\"grid\": 8, \"precision\": \"mixed\",");
    let err = GenConfig::from_json(&mixed)
        .unwrap()
        .resolve(&reg)
        .unwrap_err()
        .to_string();
    assert!(err.contains("precision"), "unexpected error: {err}");
    let sell = base.replace("\"grid\": 8,", "\"grid\": 8, \"filter_backend\": \"sell\",");
    let err = GenConfig::from_json(&sell)
        .unwrap()
        .resolve(&reg)
        .unwrap_err()
        .to_string();
    assert!(err.contains("filter_backend"), "unexpected error: {err}");
    // Unknown knob values hard-error at parse time.
    let bad = base.replace("\"grid\": 8,", "\"grid\": 8, \"precision\": \"f16\",");
    assert!(GenConfig::from_json(&bad).is_err());
    let bad = base.replace("\"grid\": 8,", "\"grid\": 8, \"filter_backend\": \"ellpack\",");
    assert!(GenConfig::from_json(&bad).is_err());
}
