//! Deterministic per-record fault injection for the solve supervision
//! layer (`GenConfig.fault_injection`).
//!
//! A [`FaultPlan`] names record ids and the fault to force on each:
//! a worker panic, a non-converging solve (exercises the escalation
//! ladder), an LDLᵀ pivot breakdown (exercises the factorization
//! recovery/degrade path), or a stall (exercises the watchdog). Solve
//! workers [`install`] the plan into a thread-local and call
//! [`begin_record`] before each solve; the solver/factorization hooks
//! ([`take_nonconvergence`], [`take_pivot_breakdown`], …) then fire for
//! exactly the armed record.
//!
//! The hooks are compiled unconditionally (no `#[cfg(test)]` seams in
//! production code paths) but cost a single thread-local `Option` check
//! when no plan is installed — the supervision bench
//! (`benches/faults.rs`) holds the clean-run overhead under 2 %.

use std::cell::RefCell;

/// One fault class an injected record is forced through.
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// Panic inside the solve worker (caught by the pipeline's
    /// `catch_unwind` isolation → quarantine record, fault `panic`).
    Panic,
    /// Force the next `times` solve attempts to return
    /// `converged = false` (the escalation ladder then retries;
    /// `times > max_retries + 1` ends in quarantine, fault
    /// `nonconvergence`).
    NonConvergence {
        /// Consecutive solve attempts to fail before behaving normally.
        times: usize,
    },
    /// Force the next LDLᵀ factorization to report a pivot breakdown
    /// (the recovery path perturbs + refactors, then degrades
    /// `shift_invert` to the extremal path, fault `factorization`).
    PivotBreakdown,
    /// Sleep for `secs` before the solve (with `solve_timeout_secs` set
    /// the watchdog abandons the record, fault `timeout`).
    Stall {
        /// Seconds to sleep inside the solve stage.
        secs: f64,
    },
}

/// Which records of a generation run are forced through which fault —
/// carried on `GenConfig.fault_injection` (never serialized; resumed
/// runs replay clean).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    /// `(record id, fault)` pairs; a record id may appear once.
    pub records: Vec<(usize, Fault)>,
}

impl FaultPlan {
    /// Plan with a single faulted record.
    pub fn single(id: usize, fault: Fault) -> Self {
        Self {
            records: vec![(id, fault)],
        }
    }
}

/// Faults armed for the record currently being solved on this thread.
#[derive(Default)]
struct Armed {
    panic: bool,
    nonconvergence: usize,
    pivot_breakdown: bool,
    stall_secs: Option<f64>,
}

thread_local! {
    static PLAN: RefCell<Option<FaultPlan>> = const { RefCell::new(None) };
    static ARMED: RefCell<Armed> = RefCell::new(Armed::default());
}

/// Install a fault plan on the current (worker) thread. Replaces any
/// previous plan and disarms the current record.
pub fn install(plan: FaultPlan) {
    PLAN.with(|p| *p.borrow_mut() = Some(plan));
    ARMED.with(|a| *a.borrow_mut() = Armed::default());
}

/// Remove the plan from the current thread (hooks become free no-ops).
pub fn clear() {
    PLAN.with(|p| *p.borrow_mut() = None);
    ARMED.with(|a| *a.borrow_mut() = Armed::default());
}

/// Arm the faults planned for record `id` (no-op without a plan).
/// Called by the solve worker immediately before each record's solve.
pub fn begin_record(id: usize) {
    PLAN.with(|p| {
        let p = p.borrow();
        let Some(plan) = p.as_ref() else { return };
        let mut armed = Armed::default();
        for (rid, fault) in &plan.records {
            if *rid != id {
                continue;
            }
            match fault {
                Fault::Panic => armed.panic = true,
                Fault::NonConvergence { times } => armed.nonconvergence = *times,
                Fault::PivotBreakdown => armed.pivot_breakdown = true,
                Fault::Stall { secs } => armed.stall_secs = Some(*secs),
            }
        }
        ARMED.with(|a| *a.borrow_mut() = armed);
    });
}

/// Whether the armed record must panic now (one-shot).
pub fn take_panic() -> bool {
    ARMED.with(|a| std::mem::take(&mut a.borrow_mut().panic))
}

/// Seconds the armed record must stall before solving (one-shot).
pub fn take_stall_secs() -> Option<f64> {
    ARMED.with(|a| a.borrow_mut().stall_secs.take())
}

/// Whether the next solve attempt must report non-convergence
/// (decrements the armed attempt count).
pub fn take_nonconvergence() -> bool {
    ARMED.with(|a| {
        let mut a = a.borrow_mut();
        if a.nonconvergence > 0 {
            a.nonconvergence -= 1;
            true
        } else {
            false
        }
    })
}

/// Whether the next LDLᵀ factorization must report a pivot breakdown
/// (one-shot).
pub fn take_pivot_breakdown() -> bool {
    ARMED.with(|a| std::mem::take(&mut a.borrow_mut().pivot_breakdown))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hooks_are_inert_without_a_plan() {
        clear();
        begin_record(3);
        assert!(!take_panic());
        assert!(!take_nonconvergence());
        assert!(!take_pivot_breakdown());
        assert!(take_stall_secs().is_none());
    }

    #[test]
    fn arms_only_the_planned_record_and_fires_once() {
        install(FaultPlan {
            records: vec![
                (2, Fault::Panic),
                (2, Fault::NonConvergence { times: 2 }),
                (5, Fault::PivotBreakdown),
            ],
        });
        begin_record(1);
        assert!(!take_panic());
        begin_record(2);
        assert!(take_panic());
        assert!(!take_panic(), "panic fault must be one-shot");
        assert!(take_nonconvergence());
        assert!(take_nonconvergence());
        assert!(!take_nonconvergence(), "times budget exhausted");
        assert!(!take_pivot_breakdown(), "armed for a different record");
        begin_record(5);
        assert!(take_pivot_breakdown());
        assert!(!take_pivot_breakdown());
        clear();
        begin_record(2);
        assert!(!take_panic());
    }

    #[test]
    fn stall_is_one_shot_per_record() {
        install(FaultPlan::single(7, Fault::Stall { secs: 0.25 }));
        begin_record(7);
        assert_eq!(take_stall_secs(), Some(0.25));
        assert_eq!(take_stall_secs(), None);
        begin_record(7);
        assert_eq!(take_stall_secs(), Some(0.25), "re-arms per begin_record");
        clear();
    }
}
