//! Thick-restart Lanczos — the SciPy `eigsh` (ARPACK) stand-in.
//!
//! For Hermitian matrices the implicitly-restarted Lanczos of ARPACK and
//! Krylov–Schur are mathematically equivalent restart schemes (Stewart
//! 2002); we implement the thick-restart formulation (Wu & Simon 2000)
//! with full reorthogonalization, and expose two restart policies:
//! the roomy ARPACK-style subspace here, and the lean
//! Krylov–Schur-style subspace in [`super::krylov_schur`].

use super::{EigOptions, EigResult, SolveStats, WarmStart};
use crate::linalg::dense::{dot, norm2, vaxpy};
use crate::linalg::symeig::sym_eig;
use crate::linalg::{flops, Mat};
use crate::rng::Xoshiro256pp;
use crate::sparse::CsrMatrix;
use std::time::Instant;

/// ARPACK-style restart dimension: `m = min(n−1, max(2(L+g), L+g+12))`.
pub fn solve(a: &CsrMatrix, opts: &EigOptions, init: Option<&WarmStart>) -> EigResult {
    let l = opts.n_eigs;
    let keep = l + super::guard_size(l);
    let m = (2 * keep).max(keep + 12).min(a.rows() - 1);
    thick_restart_engine(a, opts, init, m, keep)
}

/// The shared thick-restart Lanczos engine.
///
/// * `m_dim` — Krylov subspace dimension per cycle.
/// * `keep`  — Ritz pairs retained at each restart.
pub(crate) fn thick_restart_engine(
    a: &CsrMatrix,
    opts: &EigOptions,
    init: Option<&WarmStart>,
    m_dim: usize,
    keep: usize,
) -> EigResult {
    let t0 = Instant::now();
    flops::take();
    let n = a.rows();
    let l = opts.n_eigs;
    assert!(l >= 1 && l < n);
    let m_dim = m_dim.min(n - 1).max(l + 2);
    let keep = keep.min(m_dim - 2).max(l);
    let tol = opts.tol;
    let mut rng = Xoshiro256pp::seed_from_u64(opts.seed);
    let mut stats = SolveStats::default();

    // Basis Q: m_dim + 1 columns, stored column-contiguous for the
    // dot/axpy-heavy inner loop.
    let mut q: Vec<Vec<f64>> = Vec::with_capacity(m_dim + 1);
    // Starting vector: warm starts collapse the inherited subspace into
    // one vector (ARPACK's v0 contract — Table 2's Eigsh*/KS* variants).
    let mut v0 = vec![0.0f64; n];
    match init {
        Some(ws) => {
            for j in 0..ws.vectors.cols() {
                for i in 0..n {
                    v0[i] += ws.vectors[(i, j)];
                }
            }
            flops::add((n * ws.vectors.cols()) as u64);
        }
        None => rng.fill_normal(&mut v0),
    }
    let nrm = norm2(&v0);
    v0.iter_mut().for_each(|x| *x /= nrm);
    q.push(v0);

    let mut t = Mat::zeros(m_dim, m_dim);
    let mut start = 0usize; // index of the newest basis column to expand
    let mut w = vec![0.0f64; n];
    let mut beta_last = 0.0f64;

    loop {
        stats.iterations += 1;
        // ---- Lanczos expansion from `start` to `m_dim` -----------------
        for j in start..m_dim {
            a.spmv(&q[j], &mut w);
            stats.matvecs += 1;
            // Full reorthogonalization (two MGS passes); only the
            // (arrowhead-)tridiagonal coefficients enter T.
            for pass in 0..2 {
                for (i, qi) in q.iter().enumerate() {
                    let c = dot(qi, &w);
                    vaxpy(-c, qi, &mut w);
                    if pass == 0 && i == j {
                        t[(j, j)] += c;
                    }
                }
            }
            let beta = norm2(&w);
            if j + 1 < m_dim {
                t[(j, j + 1)] = beta;
                t[(j + 1, j)] = beta;
            } else {
                beta_last = beta;
            }
            if beta < 1e-12 {
                // Breakdown: invariant subspace found. Insert a fresh
                // random direction (decoupled: beta entry stays 0).
                let mut fresh = vec![0.0f64; n];
                rng.fill_normal(&mut fresh);
                for qi in q.iter() {
                    let c = dot(qi, &fresh);
                    vaxpy(-c, qi, &mut fresh);
                }
                let fn_ = norm2(&fresh);
                fresh.iter_mut().for_each(|x| *x /= fn_);
                if j + 1 < m_dim {
                    t[(j, j + 1)] = 0.0;
                    t[(j + 1, j)] = 0.0;
                } else {
                    beta_last = 0.0;
                }
                q.push(fresh);
            } else {
                q.push(w.iter().map(|x| x / beta).collect());
            }
        }

        // ---- Rayleigh–Ritz on T ---------------------------------------
        let eig = sym_eig(&t);
        let theta = &eig.values;
        let s = &eig.vectors;

        // Residuals of the l wanted (smallest) Ritz pairs.
        let mut n_conv = 0;
        for i in 0..l {
            let res = (beta_last * s[(m_dim - 1, i)]).abs();
            let denom = (theta[i] * theta[i] + res * res).sqrt().max(1e-300);
            if res / denom <= tol {
                n_conv += 1;
            } else {
                break;
            }
        }

        let done = n_conv >= l || stats.iterations >= opts.max_iters;
        let k_out = if done { l } else { keep };
        // Ritz vectors Y = Q_m · S[:, :k_out].
        let mut y = Mat::zeros(n, k_out);
        for col in 0..k_out {
            for i in 0..n {
                let mut acc = 0.0;
                for jj in 0..m_dim {
                    acc += q[jj][i] * s[(jj, col)];
                }
                y[(i, col)] = acc;
            }
        }
        flops::add(2 * (n * m_dim * k_out) as u64);

        if done {
            stats.flops = flops::take();
            stats.secs = t0.elapsed().as_secs_f64();
            let values = theta[..l].to_vec();
            return EigResult::finalize(a, values, y, stats, tol);
        }

        // ---- Thick restart --------------------------------------------
        let resid = q[m_dim].clone();
        q.clear();
        for c in 0..keep {
            q.push(y.col(c));
        }
        q.push(resid);
        t = Mat::zeros(m_dim, m_dim);
        for i in 0..keep {
            t[(i, i)] = theta[i];
            let b = beta_last * s[(m_dim - 1, i)];
            t[(i, keep)] = b;
            t[(keep, i)] = b;
        }
        start = keep;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::{self, GenOptions, OperatorKind};

    fn problem(kind: OperatorKind, grid: usize, seed: u64) -> CsrMatrix {
        operators::generate(
            kind,
            GenOptions {
                grid,
                ..Default::default()
            },
            1,
            seed,
        )
        .remove(0)
        .matrix
    }

    fn reference(a: &CsrMatrix, l: usize) -> Vec<f64> {
        sym_eig(&a.to_dense()).values[..l].to_vec()
    }

    #[test]
    fn converges_on_poisson() {
        let a = problem(OperatorKind::Poisson, 12, 1);
        let opts = EigOptions {
            n_eigs: 8,
            tol: 1e-10,
            max_iters: 500,
            seed: 0,
        };
        let r = solve(&a, &opts, None);
        assert!(r.stats.converged, "{:?}", r.residuals);
        for (got, want) in r.values.iter().zip(&reference(&a, 8)) {
            assert!((got - want).abs() / want < 1e-8, "{got} vs {want}");
        }
    }

    #[test]
    fn converges_on_all_operator_families() {
        for kind in [
            OperatorKind::Elliptic,
            OperatorKind::Helmholtz,
            OperatorKind::Vibration,
            OperatorKind::HelmholtzFem,
        ] {
            let a = problem(kind, 9, 2);
            let opts = EigOptions {
                n_eigs: 5,
                tol: 1e-8,
                max_iters: 500,
                seed: 1,
            };
            let r = solve(&a, &opts, None);
            assert!(r.stats.converged, "{kind:?}");
            for (got, want) in r.values.iter().zip(&reference(&a, 5)) {
                assert!(
                    (got - want).abs() / want.abs().max(1.0) < 1e-6,
                    "{kind:?}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn eigenvectors_meet_residual_tolerance() {
        let a = problem(OperatorKind::Helmholtz, 10, 3);
        let opts = EigOptions {
            n_eigs: 6,
            tol: 1e-9,
            max_iters: 500,
            seed: 2,
        };
        let r = solve(&a, &opts, None);
        for res in &r.residuals {
            assert!(*res < 1e-8, "residual {res}");
        }
    }

    #[test]
    fn warm_start_is_accepted_and_correct() {
        // Table 2: Eigsh* — warm start must not break correctness
        // (the paper found it barely helps, and ours needn't either).
        let a = problem(OperatorKind::Helmholtz, 10, 4);
        let opts = EigOptions {
            n_eigs: 5,
            tol: 1e-8,
            max_iters: 500,
            seed: 3,
        };
        let cold = solve(&a, &opts, None);
        let warm = solve(&a, &opts, Some(&cold.as_warm_start()));
        assert!(warm.stats.converged);
        for (w, c) in warm.values.iter().zip(&cold.values) {
            assert!((w - c).abs() / c.abs().max(1.0) < 1e-7);
        }
    }

    #[test]
    fn identity_matrix_degenerate_spectrum() {
        let a = CsrMatrix::eye(40);
        let opts = EigOptions {
            n_eigs: 3,
            tol: 1e-10,
            max_iters: 200,
            seed: 0,
        };
        let r = solve(&a, &opts, None);
        for v in &r.values {
            assert!((v - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn stats_are_populated() {
        let a = problem(OperatorKind::Poisson, 10, 5);
        let opts = EigOptions {
            n_eigs: 4,
            tol: 1e-8,
            max_iters: 500,
            seed: 1,
        };
        let r = solve(&a, &opts, None);
        assert!(r.stats.matvecs > 0);
        assert!(r.stats.flops > 0);
        assert!(r.stats.iterations >= 1);
        assert_eq!(r.stats.filter_flops, 0); // no Chebyshev filter here
    }
}
