"""Pure-jnp reference oracle for the Layer-1 kernel and Layer-2 filter.

These are the "obviously correct" implementations the pytest suite
compares against (and the same math the rust native backend implements,
so the three implementations triangulate each other).
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_fused_step(s, a, y, z):
    """out = s0*(a@y) + s1*y + s2*z, no tiling, no kernel."""
    return s[0] * (a @ y) + s[1] * y + s[2] * z


def ref_chebyshev_filter(a, y0, target, c, e, degree: int):
    """Scaled-and-shifted Chebyshev filter (paper Algorithm 1).

    Mirrors `scsf::eig::chebyshev::chebyshev_filter` in rust:

        Y1   = (s1/e) * (A - c I) Y0
        Yi+1 = 2*(s'/e) * (A - c I) Yi - s*s' * Yi-1

    with s1 = e / (target - c) and s' = 1 / (2/s1 - s).
    """
    sigma1 = e / (target - c)
    sigma = sigma1
    y_prev = y0
    y_cur = (sigma1 / e) * (a @ y0) - (c * sigma1 / e) * y0
    for _ in range(1, degree):
        sigma_new = 1.0 / (2.0 / sigma1 - sigma)
        y_next = (
            (2.0 * sigma_new / e) * (a @ y_cur)
            - (2.0 * c * sigma_new / e) * y_cur
            - (sigma * sigma_new) * y_prev
        )
        y_prev, y_cur = y_cur, y_next
        sigma = sigma_new
    return y_cur


def ref_scalar_filter(t, target, c, e, degree: int):
    """Scalar filter value rho_m(t) (matches FilterParams::eval_scalar)."""
    sigma1 = e / (target - c)
    sigma = sigma1
    ym = (t - c) / e * sigma1
    ymm = jnp.ones_like(t) if hasattr(t, "shape") else 1.0
    for _ in range(1, degree):
        sigma_new = 1.0 / (2.0 / sigma1 - sigma)
        y = 2.0 * ((t - c) / e) * sigma_new * ym - sigma * sigma_new * ymm
        ymm, ym = ym, y
        sigma = sigma_new
    return ym


def ref_residual_norms(a, v, lams):
    """Relative residuals ||A v_j - lam_j v_j|| / ||A v_j|| per column."""
    av = a @ v
    num = jnp.linalg.norm(av - v * lams[None, :], axis=0)
    den = jnp.linalg.norm(av, axis=0)
    return num / den
