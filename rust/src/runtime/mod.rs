//! PJRT runtime bridge: loads the AOT-compiled JAX/Pallas artifacts
//! (HLO text, see `python/compile/aot.py`) and exposes them to the L3
//! hot path. Python never runs here — the artifacts are self-contained
//! XLA programs compiled once per process by the PJRT CPU client.
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;
pub mod filter_exec;
pub mod xla_stub;

pub use artifact::{ArtifactKind, ArtifactMeta, XlaRuntime};
pub use filter_exec::XlaFilter;
