//! Minimal in-tree replacement for the `anyhow` crate.
//!
//! The offline build environment has no registry access, so the crate
//! graph must be dependency-free. This module provides the narrow
//! subset the codebase uses — a string-backed [`Error`], the [`Result`]
//! alias, the [`anyhow!`]/[`bail!`] macros, and a [`Context`] extension
//! trait — with the same call-site syntax, so swapping the real crate
//! back in later is a five-line import change (see DESIGN.md §Offline
//! dependencies).

use std::fmt;

/// A string-backed error. Like `anyhow::Error` it deliberately does
/// *not* implement `std::error::Error`, which is what makes the blanket
/// `From` conversion below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self {
            msg: m.to_string(),
        }
    }

    /// Wrap with a context prefix (used by [`Context`]).
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Self {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// `?`-operator conversion from any standard error type.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Result alias defaulting the error type, as `anyhow::Result` does.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazily-built context to a fallible value.
pub trait Context<T> {
    /// Wrap the error with `f()` as a prefix.
    fn with_context<S: fmt::Display, F: FnOnce() -> S>(self, f: F) -> Result<T>;

    /// Wrap the error with a fixed prefix.
    fn context<S: fmt::Display>(self, ctx: S) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn with_context<S: fmt::Display, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }

    fn context<S: fmt::Display>(self, ctx: S) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }
}

impl<T> Context<T> for Option<T> {
    fn with_context<S: fmt::Display, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }

    fn context<S: fmt::Display>(self, ctx: S) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
}

/// Format an [`Error`] from format-string arguments (`anyhow!` stand-in).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`] (`bail!` stand-in).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn macro_formats() {
        let e = crate::anyhow!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<usize> {
            if flag {
                crate::bail!("flagged {flag}");
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged true");
    }

    #[test]
    fn context_wraps_messages() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| "writing report").unwrap_err();
        assert!(e.to_string().starts_with("writing report: "));
        let o: Option<usize> = None;
        let e = o.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }
}
