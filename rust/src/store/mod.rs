//! Streaming, resumable dataset store.
//!
//! The layers, bottom-up — each ignorant of the ones above it:
//!
//! * [`crc32`] — the checksum sealing every manifest frame.
//! * [`pull`] — zero-allocation pull/event JSON parser (caller-owned
//!   scratch, borrowed strings, no intermediate `Value` tree).
//! * [`emit`] — streaming JSON writer, byte-compatible with the tree
//!   serializer in [`crate::util::json`].
//! * [`chunk`] — length+checksum frame pairs over an append-only file:
//!   write, fsync, scan, torn-tail detection.
//!
//! Manifest *semantics* — the schema-v3 chunked format, checkpoints,
//! crash-resume, and the streaming reader — live in
//! [`crate::coordinator::dataset`], built on these layers. The resume
//! protocol (deterministic schedule replay, per-run warm-chain
//! re-seeding) is in [`crate::coordinator::pipeline`]. See DESIGN.md
//! §Streaming store for the on-disk layout and compat matrix.

pub mod chunk;
pub mod crc32;
pub mod emit;
pub mod pull;

pub use chunk::{FrameScanner, FrameWriter};
pub use emit::JsonEmitter;
pub use pull::{Event, PullParser, RawStr};
