//! Compressed-sparse-row matrix, COO assembly, SpMV / SpMM kernels.

use crate::linalg::dense::{Mat, MatF32};
use crate::linalg::flops;

/// Coordinate-format assembly buffer. Duplicate `(i, j)` entries are
/// summed on conversion — the natural contract for FDM/FEM assembly.
#[derive(Debug, Clone, Default)]
pub struct CooBuilder {
    rows: usize,
    cols: usize,
    entries: Vec<(u32, u32, f64)>,
}

impl CooBuilder {
    /// New builder for an `rows × cols` matrix.
    pub fn new(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            entries: Vec::new(),
        }
    }

    /// Add `v` at `(i, j)` (accumulates with duplicates).
    #[inline]
    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols, "entry out of bounds");
        if v != 0.0 {
            self.entries.push((i as u32, j as u32, v));
        }
    }

    /// Number of raw (pre-merge) entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if no entries have been pushed.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Convert to CSR, merging duplicates.
    pub fn build(mut self) -> CsrMatrix {
        self.entries
            .sort_unstable_by_key(|&(i, j, _)| ((i as u64) << 32) | j as u64);
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices: Vec<u32> = Vec::with_capacity(self.entries.len());
        let mut values: Vec<f64> = Vec::with_capacity(self.entries.len());
        let mut prev: Option<(u32, u32)> = None;
        for &(i, j, v) in &self.entries {
            if prev == Some((i, j)) {
                // Duplicate coordinate: accumulate.
                *values.last_mut().unwrap() += v;
            } else {
                indices.push(j);
                values.push(v);
                indptr[i as usize + 1] = indices.len();
                prev = Some((i, j));
            }
        }
        // Fill empty-row gaps (rows with no entries keep previous offset).
        for i in 1..=self.rows {
            if indptr[i] == 0 {
                indptr[i] = indptr[i - 1];
            }
        }
        CsrMatrix {
            rows: self.rows,
            cols: self.cols,
            indptr,
            indices,
            values,
        }
    }
}

/// CSR sparse matrix (`f64` values, `u32` column indices).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut b = CooBuilder::new(n, n);
        for i in 0..n {
            b.push(i, i, 1.0);
        }
        b.build()
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Row `i` as (column-indices, values) slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Value at `(i, j)` (O(row nnz)).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (cols, vals) = self.row(i);
        for (c, v) in cols.iter().zip(vals) {
            if *c as usize == j {
                return *v;
            }
        }
        0.0
    }

    /// Diagonal entries.
    pub fn diagonal(&self) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, i)).collect()
    }

    /// Maximum asymmetry `max |a_ij − a_ji|` — validation helper; all the
    /// paper's operators are self-adjoint so this must be ~0 after
    /// discretization (symmetrized assembly).
    pub fn asymmetry(&self) -> f64 {
        let mut worst: f64 = 0.0;
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                worst = worst.max((v - self.get(*c as usize, i)).abs());
            }
        }
        worst
    }

    /// Sparse matrix–vector product `y = A x`.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        flops::add(2 * self.nnz() as u64);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let mut acc = 0.0;
            for (c, v) in cols.iter().zip(vals) {
                acc += v * x[*c as usize];
            }
            y[i] = acc;
        }
    }

    /// Allocating SpMV.
    pub fn spmv_alloc(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.spmv(x, &mut y);
        y
    }

    /// Sparse matrix × dense block: `Y = A X`, with `X: cols × k` row-major.
    ///
    /// The row-major layout makes the inner loop a unit-stride AXPY over
    /// the `k` columns, which auto-vectorizes; this routine dominates SCSF
    /// runtime (Chebyshev filter, paper Table 11).
    pub fn spmm(&self, x: &Mat, y: &mut Mat) {
        assert_eq!(x.rows(), self.cols, "spmm shape: A.cols == X.rows");
        assert_eq!(y.rows(), self.rows);
        assert_eq!(y.cols(), x.cols());
        let k = x.cols();
        flops::add(2 * (self.nnz() * k) as u64);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let yrow = y.row_mut(i);
            yrow.fill(0.0);
            for (c, v) in cols.iter().zip(vals) {
                let xrow = x.row(*c as usize);
                let a = *v;
                for t in 0..k {
                    yrow[t] += a * xrow[t];
                }
            }
        }
    }

    /// Allocating SpMM.
    pub fn spmm_alloc(&self, x: &Mat) -> Mat {
        let mut y = Mat::zeros(self.rows, x.cols());
        self.spmm(x, &mut y);
        y
    }

    /// Row boundaries balancing stored non-zeros across `nt` chunks
    /// (`len == nt + 1`, starts at 0, ends at `rows`, nondecreasing).
    /// FEM/elliptic assemblies have uneven rows, so equal-row splits
    /// leave threads idle while one chunk owns most of the matrix; the
    /// threaded kernels partition by nnz instead. Changing the split
    /// never changes results — every row keeps its serial accumulation
    /// order.
    pub fn nnz_splits(&self, nt: usize) -> Vec<usize> {
        let mut splits = Vec::with_capacity(nt + 1);
        splits.push(0usize);
        for t in 1..=nt {
            let prev = *splits.last().unwrap();
            splits.push(self.nnz_split_at(t, nt, prev));
        }
        splits
    }

    /// Boundary `t` of the nnz partition (the single formula behind
    /// [`CsrMatrix::nnz_splits`]): first row whose cumulative nnz
    /// reaches `t/nt` of the total, clamped monotone past `prev`. The
    /// threaded kernels call this directly so the hot path stays
    /// allocation-free.
    #[inline]
    fn nnz_split_at(&self, t: usize, nt: usize, prev: usize) -> usize {
        if t >= nt {
            return self.rows;
        }
        let target = self.nnz() * t / nt;
        self.indptr
            .partition_point(|&x| x < target)
            .min(self.rows)
            .max(prev)
    }

    /// Non-allocating SpMV with optional nnz-partitioned threading:
    /// `y = A x`, computed on `threads` scoped threads (`≤ 1` → the
    /// serial kernel). Each row is accumulated in the same order as the
    /// serial kernel, so results are bit-for-bit identical for every
    /// thread count.
    pub fn spmv_into(&self, x: &[f64], y: &mut [f64], threads: usize) {
        let nt = threads.max(1).min(self.rows.max(1));
        if nt <= 1 || self.rows == 0 {
            self.spmv(x, y);
            return;
        }
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        // Worker flops are accounted on the dispatching thread — the
        // thread-local counter never sees the scoped workers.
        flops::add(2 * self.nnz() as u64);
        std::thread::scope(|scope| {
            let mut rest = &mut y[..];
            let mut row0 = 0usize;
            for t in 1..=nt {
                let row1 = self.nnz_split_at(t, nt, row0);
                let (ychunk, tail) = rest.split_at_mut(row1 - row0);
                rest = tail;
                let r0 = row0;
                row0 = row1;
                if row1 == r0 {
                    continue;
                }
                scope.spawn(move || {
                    for (r, yi) in ychunk.iter_mut().enumerate() {
                        let (cols, vals) = self.row(r0 + r);
                        let mut acc = 0.0;
                        for (c, v) in cols.iter().zip(vals) {
                            acc += v * x[*c as usize];
                        }
                        *yi = acc;
                    }
                });
            }
        });
    }

    /// Non-allocating SpMM with optional nnz-partitioned threading:
    /// `Y = A X` on `threads` scoped threads (`≤ 1` → the serial
    /// kernel). The row blocks are disjoint and every row uses the
    /// serial accumulation order, so the result is deterministic —
    /// bit-for-bit equal to [`CsrMatrix::spmm`] — for any thread count.
    pub fn spmm_into(&self, x: &Mat, y: &mut Mat, threads: usize) {
        let k = x.cols();
        // Every output entry is written below; skip the resize memset.
        y.set_shape(self.rows, k);
        let nt = threads.max(1).min(self.rows.max(1));
        if nt <= 1 || self.rows == 0 || k == 0 {
            self.spmm(x, y);
            return;
        }
        self.spmm_cols_into(x, y, 0, k, threads);
    }

    /// Column-windowed SpMM: `Y[:, j0..j1] = (A X)[:, j0..j1]`, with
    /// `X` and `Y` full-width row-major blocks of equal column count.
    /// Columns outside the window are left untouched, which is what
    /// makes the adaptive filter's shrinking window zero-cost — retired
    /// columns simply stop being part of the kernel's sub-slices. `Y`
    /// must already have the output shape (unlike
    /// [`CsrMatrix::spmm_into`], which reshapes, this kernel preserves
    /// the unwindowed columns). Bit-for-bit deterministic for any
    /// thread count, and identical on the window to the full kernel.
    pub fn spmm_cols_into(&self, x: &Mat, y: &mut Mat, j0: usize, j1: usize, threads: usize) {
        let k = x.cols();
        assert_eq!(x.rows(), self.cols, "spmm shape: A.cols == X.rows");
        assert_eq!((y.rows(), y.cols()), (self.rows, k), "spmm_cols_into output shape");
        assert!(j0 <= j1 && j1 <= k, "column window out of range");
        if j0 == j1 || self.rows == 0 {
            return;
        }
        flops::add(2 * (self.nnz() * (j1 - j0)) as u64);
        let nt = threads.max(1).min(self.rows.max(1));
        let yd = y.data_mut();
        if nt <= 1 {
            self.spmm_cols_rows(x, yd, 0, j0, j1, k);
            return;
        }
        std::thread::scope(|scope| {
            let mut rest = yd;
            let mut row0 = 0usize;
            for t in 1..=nt {
                let row1 = self.nnz_split_at(t, nt, row0);
                let (ychunk, tail) = rest.split_at_mut((row1 - row0) * k);
                rest = tail;
                let r0 = row0;
                row0 = row1;
                if row1 == r0 {
                    continue;
                }
                scope.spawn(move || self.spmm_cols_rows(x, ychunk, r0, j0, j1, k));
            }
        });
    }

    /// One row-chunk of the windowed SpMM (shared by the serial and
    /// threaded paths so their arithmetic cannot drift).
    fn spmm_cols_rows(
        &self,
        x: &Mat,
        ychunk: &mut [f64],
        row0: usize,
        j0: usize,
        j1: usize,
        k: usize,
    ) {
        let w = j1 - j0;
        for (r, yrow) in ychunk.chunks_mut(k).enumerate() {
            let (cols, vals) = self.row(row0 + r);
            let ywin = &mut yrow[j0..j1];
            ywin.fill(0.0);
            for (c, v) in cols.iter().zip(vals) {
                let xrow = &x.row(*c as usize)[j0..j1];
                let a = *v;
                for t in 0..w {
                    ywin[t] += a * xrow[t];
                }
            }
        }
    }

    /// Fused filter step `Y = a·(A X) + b·X + c·Z` — one pass over A plus
    /// one pass over the dense blocks. This is exactly the shape of the
    /// Chebyshev three-term recurrence (Algorithm 1, line 5) and avoids
    /// materializing the intermediate `A X`.
    pub fn spmm_fused(&self, a: f64, x: &Mat, b: f64, c: f64, z: &Mat, y: &mut Mat) {
        assert_eq!(x.rows(), self.cols);
        assert_eq!(z.rows(), self.rows);
        assert_eq!(y.rows(), self.rows);
        let k = x.cols();
        assert!(z.cols() == k && y.cols() == k);
        flops::add((2 * self.nnz() * k + 4 * self.rows * k) as u64);
        let xd = x.data();
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let yrow = y.row_mut(i);
            // Initialize with the dense terms, then accumulate the sparse
            // row — one pass over yrow less than the fill(0.0) variant.
            let xrow = &xd[i * k..(i + 1) * k];
            let zrow = z.row(i);
            for t in 0..k {
                yrow[t] = b * xrow[t] + c * zrow[t];
            }
            for (cc, v) in cols.iter().zip(vals) {
                let xr = &xd[*cc as usize * k..(*cc as usize + 1) * k];
                let s = a * *v;
                for t in 0..k {
                    yrow[t] += s * xr[t];
                }
            }
        }
    }

    /// Threaded variant of [`CsrMatrix::spmm_fused`] — the Chebyshev
    /// three-term step `Y = a·(A X) + b·X + c·Z` nnz-partitioned over
    /// `threads` scoped threads (`≤ 1` → the serial kernel), with the
    /// same per-row accumulation order and therefore bit-for-bit
    /// deterministic output for any thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn spmm_fused_into(
        &self,
        a: f64,
        x: &Mat,
        b: f64,
        c: f64,
        z: &Mat,
        y: &mut Mat,
        threads: usize,
    ) {
        let k = x.cols();
        // Every output entry is written below; skip the resize memset.
        y.set_shape(self.rows, k);
        let nt = threads.max(1).min(self.rows.max(1));
        if nt <= 1 || self.rows == 0 || k == 0 {
            self.spmm_fused(a, x, b, c, z, y);
            return;
        }
        self.spmm_fused_cols_into(a, x, b, c, z, y, 0, k, threads);
    }

    /// Column-windowed fused filter step:
    /// `Y[:, j0..j1] = a·(A X)[:, j0..j1] + b·X[:, j0..j1] + c·Z[:, j0..j1]`
    /// with full-width blocks; columns outside the window are left
    /// untouched. This is the kernel behind the adaptive filter's
    /// shrinking column window ([`crate::eig::chebyshev`]): a column
    /// that reached its scheduled degree simply drops out of the
    /// sub-slices — no copies, no compaction. `Y` must already have the
    /// output shape. Bit-for-bit deterministic for any thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn spmm_fused_cols_into(
        &self,
        a: f64,
        x: &Mat,
        b: f64,
        c: f64,
        z: &Mat,
        y: &mut Mat,
        j0: usize,
        j1: usize,
        threads: usize,
    ) {
        let k = x.cols();
        assert_eq!(x.rows(), self.cols);
        assert_eq!(z.rows(), self.rows);
        assert!(z.cols() == k);
        assert_eq!(
            (y.rows(), y.cols()),
            (self.rows, k),
            "spmm_fused_cols_into output shape"
        );
        assert!(j0 <= j1 && j1 <= k, "column window out of range");
        if j0 == j1 || self.rows == 0 {
            return;
        }
        flops::add((2 * self.nnz() * (j1 - j0) + 4 * self.rows * (j1 - j0)) as u64);
        let nt = threads.max(1).min(self.rows.max(1));
        let xd = x.data();
        let yd = y.data_mut();
        if nt <= 1 {
            self.spmm_fused_cols_rows(a, xd, b, c, z, yd, 0, j0, j1, k);
            return;
        }
        std::thread::scope(|scope| {
            let mut rest = yd;
            let mut row0 = 0usize;
            for t in 1..=nt {
                let row1 = self.nnz_split_at(t, nt, row0);
                let (ychunk, tail) = rest.split_at_mut((row1 - row0) * k);
                rest = tail;
                let r0 = row0;
                row0 = row1;
                if row1 == r0 {
                    continue;
                }
                scope.spawn(move || {
                    self.spmm_fused_cols_rows(a, xd, b, c, z, ychunk, r0, j0, j1, k)
                });
            }
        });
    }

    /// One row-chunk of the windowed fused step (shared by the serial
    /// and threaded paths so their arithmetic cannot drift).
    #[allow(clippy::too_many_arguments)]
    fn spmm_fused_cols_rows(
        &self,
        a: f64,
        xd: &[f64],
        b: f64,
        c: f64,
        z: &Mat,
        ychunk: &mut [f64],
        row0: usize,
        j0: usize,
        j1: usize,
        k: usize,
    ) {
        let w = j1 - j0;
        for (r, yrow) in ychunk.chunks_mut(k).enumerate() {
            let i = row0 + r;
            let (cols, vals) = self.row(i);
            let ywin = &mut yrow[j0..j1];
            let xrow = &xd[i * k + j0..i * k + j1];
            let zrow = &z.row(i)[j0..j1];
            for t in 0..w {
                ywin[t] = b * xrow[t] + c * zrow[t];
            }
            for (cc, v) in cols.iter().zip(vals) {
                let xr = &xd[*cc as usize * k + j0..*cc as usize * k + j1];
                let s = a * *v;
                for t in 0..w {
                    ywin[t] += s * xr[t];
                }
            }
        }
    }

    /// Dense copy (test/diagnostic helper and the densified input of the
    /// XLA filter backend at small n).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                m[(i, *c as usize)] = *v;
            }
        }
        m
    }

    /// `A + alpha·I` (spectral shifts for indefinite Helmholtz handling).
    pub fn shift(&self, alpha: f64) -> CsrMatrix {
        assert_eq!(self.rows, self.cols);
        let mut b = CooBuilder::new(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                b.push(i, *c as usize, *v);
            }
            b.push(i, i, alpha);
        }
        b.build()
    }

    /// `A + alpha·B` with sparsity-union structure (the shifted pencil
    /// `K = A − σM` of the shift-invert transform). Both operands must
    /// share dimensions.
    pub fn add_scaled(&self, alpha: f64, other: &CsrMatrix) -> CsrMatrix {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        let mut b = CooBuilder::new(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                b.push(i, *c as usize, *v);
            }
            let (cols, vals) = other.row(i);
            for (c, v) in cols.iter().zip(vals) {
                b.push(i, *c as usize, alpha * *v);
            }
        }
        b.build()
    }

    /// Scale all values by `alpha`.
    pub fn scaled(&self, alpha: f64) -> CsrMatrix {
        let mut out = self.clone();
        for v in &mut out.values {
            *v *= alpha;
        }
        out
    }

    /// 1-norm (max column sum of |a_ij|) — cheap upper bound for the
    /// spectral radius used to safeguard the filter interval.
    pub fn norm1(&self) -> f64 {
        let mut colsum = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                colsum[*c as usize] += v.abs();
            }
        }
        colsum.into_iter().fold(0.0, f64::max)
    }
}

/// CSR sparse matrix with `f32` values — the operator storage of the
/// mixed-precision Chebyshev sweeps.
///
/// Built once per solve by downcasting a [`CsrMatrix`] (the structure —
/// `indptr`/`indices` — is copied verbatim, only the values round). The
/// kernels mirror the f64 ones exactly: same nnz-balanced row
/// partitions, same per-row serial accumulation order, hence bit-for-bit
/// deterministic for any thread count.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrixF32 {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl CsrMatrixF32 {
    /// Downcast copy of an f64 CSR matrix (round-to-nearest values,
    /// identical sparsity structure).
    pub fn from_f64(a: &CsrMatrix) -> Self {
        Self {
            rows: a.rows,
            cols: a.cols,
            indptr: a.indptr.clone(),
            indices: a.indices.clone(),
            values: a.values.iter().map(|&v| v as f32).collect(),
        }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Row `i` as (column-indices, values) slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Boundary `t` of the nnz partition — the same formula as the f64
    /// matrix's `nnz_split_at`, so both precisions share one
    /// partitioning scheme.
    #[inline]
    fn nnz_split_at(&self, t: usize, nt: usize, prev: usize) -> usize {
        if t >= nt {
            return self.rows;
        }
        let target = self.nnz() * t / nt;
        self.indptr
            .partition_point(|&x| x < target)
            .min(self.rows)
            .max(prev)
    }

    /// Non-allocating f32 SpMM `Y = A X` with optional nnz-partitioned
    /// threading — the f32 sibling of [`CsrMatrix::spmm_into`].
    pub fn spmm_into(&self, x: &MatF32, y: &mut MatF32, threads: usize) {
        let k = x.cols();
        y.set_shape(self.rows, k);
        if self.rows == 0 || k == 0 {
            return;
        }
        self.spmm_cols_into(x, y, 0, k, threads);
    }

    /// Column-windowed f32 SpMM: `Y[:, j0..j1] = (A X)[:, j0..j1]`,
    /// columns outside the window untouched — the f32 sibling of
    /// [`CsrMatrix::spmm_cols_into`], deterministic for any thread
    /// count.
    pub fn spmm_cols_into(&self, x: &MatF32, y: &mut MatF32, j0: usize, j1: usize, threads: usize) {
        let k = x.cols();
        assert_eq!(x.rows(), self.cols, "spmm shape: A.cols == X.rows");
        assert_eq!((y.rows(), y.cols()), (self.rows, k), "spmm_cols_into output shape");
        assert!(j0 <= j1 && j1 <= k, "column window out of range");
        if j0 == j1 || self.rows == 0 {
            return;
        }
        flops::add(2 * (self.nnz() * (j1 - j0)) as u64);
        let nt = threads.max(1).min(self.rows.max(1));
        let yd = y.data_mut();
        if nt <= 1 {
            self.spmm_cols_rows(x, yd, 0, j0, j1, k);
            return;
        }
        std::thread::scope(|scope| {
            let mut rest = yd;
            let mut row0 = 0usize;
            for t in 1..=nt {
                let row1 = self.nnz_split_at(t, nt, row0);
                let (ychunk, tail) = rest.split_at_mut((row1 - row0) * k);
                rest = tail;
                let r0 = row0;
                row0 = row1;
                if row1 == r0 {
                    continue;
                }
                scope.spawn(move || self.spmm_cols_rows(x, ychunk, r0, j0, j1, k));
            }
        });
    }

    /// One row-chunk of the windowed f32 SpMM (shared by the serial and
    /// threaded paths so their arithmetic cannot drift).
    fn spmm_cols_rows(
        &self,
        x: &MatF32,
        ychunk: &mut [f32],
        row0: usize,
        j0: usize,
        j1: usize,
        k: usize,
    ) {
        let w = j1 - j0;
        for (r, yrow) in ychunk.chunks_mut(k).enumerate() {
            let (cols, vals) = self.row(row0 + r);
            let ywin = &mut yrow[j0..j1];
            ywin.fill(0.0);
            for (c, v) in cols.iter().zip(vals) {
                let xrow = &x.row(*c as usize)[j0..j1];
                let a = *v;
                for t in 0..w {
                    ywin[t] += a * xrow[t];
                }
            }
        }
    }

    /// Threaded f32 fused filter step `Y = a·(A X) + b·X + c·Z` — the
    /// f32 sibling of [`CsrMatrix::spmm_fused_into`].
    #[allow(clippy::too_many_arguments)]
    pub fn spmm_fused_into(
        &self,
        a: f32,
        x: &MatF32,
        b: f32,
        c: f32,
        z: &MatF32,
        y: &mut MatF32,
        threads: usize,
    ) {
        let k = x.cols();
        y.set_shape(self.rows, k);
        if self.rows == 0 || k == 0 {
            return;
        }
        self.spmm_fused_cols_into(a, x, b, c, z, y, 0, k, threads);
    }

    /// Column-windowed f32 fused filter step — the f32 sibling of
    /// [`CsrMatrix::spmm_fused_cols_into`]: columns outside the window
    /// are untouched, results are bit-for-bit deterministic for any
    /// thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn spmm_fused_cols_into(
        &self,
        a: f32,
        x: &MatF32,
        b: f32,
        c: f32,
        z: &MatF32,
        y: &mut MatF32,
        j0: usize,
        j1: usize,
        threads: usize,
    ) {
        let k = x.cols();
        assert_eq!(x.rows(), self.cols);
        assert_eq!(z.rows(), self.rows);
        assert!(z.cols() == k);
        assert_eq!(
            (y.rows(), y.cols()),
            (self.rows, k),
            "spmm_fused_cols_into output shape"
        );
        assert!(j0 <= j1 && j1 <= k, "column window out of range");
        if j0 == j1 || self.rows == 0 {
            return;
        }
        flops::add((2 * self.nnz() * (j1 - j0) + 4 * self.rows * (j1 - j0)) as u64);
        let nt = threads.max(1).min(self.rows.max(1));
        let xd = x.data();
        let yd = y.data_mut();
        if nt <= 1 {
            self.spmm_fused_cols_rows(a, xd, b, c, z, yd, 0, j0, j1, k);
            return;
        }
        std::thread::scope(|scope| {
            let mut rest = yd;
            let mut row0 = 0usize;
            for t in 1..=nt {
                let row1 = self.nnz_split_at(t, nt, row0);
                let (ychunk, tail) = rest.split_at_mut((row1 - row0) * k);
                rest = tail;
                let r0 = row0;
                row0 = row1;
                if row1 == r0 {
                    continue;
                }
                scope.spawn(move || {
                    self.spmm_fused_cols_rows(a, xd, b, c, z, ychunk, r0, j0, j1, k)
                });
            }
        });
    }

    /// One row-chunk of the windowed f32 fused step.
    #[allow(clippy::too_many_arguments)]
    fn spmm_fused_cols_rows(
        &self,
        a: f32,
        xd: &[f32],
        b: f32,
        c: f32,
        z: &MatF32,
        ychunk: &mut [f32],
        row0: usize,
        j0: usize,
        j1: usize,
        k: usize,
    ) {
        let w = j1 - j0;
        for (r, yrow) in ychunk.chunks_mut(k).enumerate() {
            let i = row0 + r;
            let (cols, vals) = self.row(i);
            let ywin = &mut yrow[j0..j1];
            let xrow = &xd[i * k + j0..i * k + j1];
            let zrow = &z.row(i)[j0..j1];
            for t in 0..w {
                ywin[t] = b * xrow[t] + c * zrow[t];
            }
            for (cc, v) in cols.iter().zip(vals) {
                let xr = &xd[*cc as usize * k + j0..*cc as usize * k + j1];
                let s = a * *v;
                for t in 0..w {
                    ywin[t] += s * xr[t];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn small() -> CsrMatrix {
        // [[2, -1, 0], [-1, 2, -1], [0, -1, 2]]
        let mut b = CooBuilder::new(3, 3);
        for i in 0..3 {
            b.push(i, i, 2.0);
        }
        b.push(0, 1, -1.0);
        b.push(1, 0, -1.0);
        b.push(1, 2, -1.0);
        b.push(2, 1, -1.0);
        b.build()
    }

    #[test]
    fn coo_build_and_get() {
        let a = small();
        assert_eq!(a.nnz(), 7);
        assert_eq!(a.get(0, 0), 2.0);
        assert_eq!(a.get(0, 1), -1.0);
        assert_eq!(a.get(0, 2), 0.0);
    }

    #[test]
    fn duplicates_are_summed() {
        let mut b = CooBuilder::new(2, 2);
        b.push(0, 0, 1.0);
        b.push(0, 0, 2.5);
        b.push(1, 1, 1.0);
        let a = b.build();
        assert_eq!(a.get(0, 0), 3.5);
        assert_eq!(a.nnz(), 2);
    }

    #[test]
    fn empty_rows_handled() {
        let mut b = CooBuilder::new(4, 4);
        b.push(0, 0, 1.0);
        b.push(3, 3, 2.0);
        let a = b.build();
        assert_eq!(a.row(1).0.len(), 0);
        assert_eq!(a.row(2).0.len(), 0);
        assert_eq!(a.get(3, 3), 2.0);
        let y = a.spmv_alloc(&[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(y, vec![1.0, 0.0, 0.0, 2.0]);
    }

    #[test]
    fn spmv_matches_dense() {
        let a = small();
        let x = vec![1.0, 2.0, 3.0];
        let y = a.spmv_alloc(&x);
        assert_eq!(y, vec![0.0, 0.0, 4.0]);
    }

    #[test]
    fn spmm_matches_repeated_spmv() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut b = CooBuilder::new(20, 20);
        for _ in 0..80 {
            b.push(rng.next_below(20), rng.next_below(20), rng.normal());
        }
        for i in 0..20 {
            b.push(i, i, 4.0);
        }
        let a = b.build();
        let x = Mat::randn(20, 5, &mut rng);
        let y = a.spmm_alloc(&x);
        for j in 0..5 {
            let xj = x.col(j);
            let yj = a.spmv_alloc(&xj);
            for i in 0..20 {
                assert!((y[(i, j)] - yj[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn spmm_fused_matches_unfused() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let a = small();
        let x = Mat::randn(3, 4, &mut rng);
        let z = Mat::randn(3, 4, &mut rng);
        let mut fused = Mat::zeros(3, 4);
        a.spmm_fused(2.0, &x, -0.5, 0.25, &z, &mut fused);
        let mut unfused = a.spmm_alloc(&x);
        unfused.scale(2.0);
        unfused.axpy(-0.5, &x);
        unfused.axpy(0.25, &z);
        assert!(fused.max_abs_diff(&unfused) < 1e-13);
    }

    #[test]
    fn to_dense_roundtrip() {
        let a = small();
        let d = a.to_dense();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(d[(i, j)], a.get(i, j));
            }
        }
    }

    #[test]
    fn shift_adds_to_diagonal() {
        let a = small().shift(10.0);
        assert_eq!(a.get(0, 0), 12.0);
        assert_eq!(a.get(0, 1), -1.0);
    }

    #[test]
    fn eye_and_norm1() {
        let i = CsrMatrix::eye(5);
        assert_eq!(i.nnz(), 5);
        assert_eq!(i.norm1(), 1.0);
        assert_eq!(small().norm1(), 4.0);
    }

    #[test]
    fn symmetric_laplacian_reports_zero_asymmetry() {
        assert_eq!(small().asymmetry(), 0.0);
    }

    fn random_square(n: usize, nnz: usize, seed: u64) -> CsrMatrix {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut b = CooBuilder::new(n, n);
        for _ in 0..nnz {
            b.push(rng.next_below(n), rng.next_below(n), rng.normal());
        }
        for i in 0..n {
            b.push(i, i, 4.0);
        }
        b.build()
    }

    #[test]
    fn spmm_into_threaded_is_bit_for_bit_serial() {
        let a = random_square(37, 200, 3);
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let x = Mat::randn(37, 6, &mut rng);
        let serial = a.spmm_alloc(&x);
        for threads in [1usize, 2, 3, 4, 8, 64] {
            let mut y = Mat::zeros(0, 0);
            a.spmm_into(&x, &mut y, threads);
            assert_eq!(y, serial, "threads = {threads}");
        }
    }

    #[test]
    fn spmv_into_threaded_is_bit_for_bit_serial() {
        let a = random_square(41, 160, 5);
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let mut x = vec![0.0; 41];
        rng.fill_normal(&mut x);
        let serial = a.spmv_alloc(&x);
        for threads in [1usize, 2, 4, 7] {
            let mut y = vec![0.0; 41];
            a.spmv_into(&x, &mut y, threads);
            assert_eq!(y, serial, "threads = {threads}");
        }
    }

    #[test]
    fn spmm_fused_into_threaded_is_bit_for_bit_serial() {
        let a = random_square(29, 120, 7);
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let x = Mat::randn(29, 5, &mut rng);
        let z = Mat::randn(29, 5, &mut rng);
        let mut serial = Mat::zeros(29, 5);
        a.spmm_fused(1.7, &x, -0.3, 0.9, &z, &mut serial);
        for threads in [2usize, 3, 5] {
            let mut y = Mat::zeros(0, 0);
            a.spmm_fused_into(1.7, &x, -0.3, 0.9, &z, &mut y, threads);
            assert_eq!(y, serial, "threads = {threads}");
        }
    }

    #[test]
    fn nnz_splits_partition_rows_and_balance_nonzeros() {
        // Heavily skewed matrix: one dense row, the rest near-empty.
        let mut b = CooBuilder::new(40, 40);
        for j in 0..40 {
            b.push(3, j, 1.0);
        }
        for i in 0..40 {
            b.push(i, i, 2.0);
        }
        let a = b.build();
        for nt in [1usize, 2, 3, 5, 8] {
            let s = a.nnz_splits(nt);
            assert_eq!(s.len(), nt + 1);
            assert_eq!(s[0], 0);
            assert_eq!(s[nt], 40);
            assert!(s.windows(2).all(|w| w[0] <= w[1]), "{s:?}");
        }
        // With 2 threads the dense row must not drag half the row count
        // with it: the first chunk ends right after the heavy row.
        let s = a.nnz_splits(2);
        assert!(s[1] <= 5, "nnz split ignored the dense row: {s:?}");
    }

    #[test]
    fn windowed_spmm_matches_full_kernel_on_the_window() {
        let a = random_square(33, 250, 9);
        let mut rng = Xoshiro256pp::seed_from_u64(10);
        let x = Mat::randn(33, 7, &mut rng);
        let full = a.spmm_alloc(&x);
        for (j0, j1) in [(0usize, 7usize), (0, 4), (2, 7), (3, 3), (1, 6)] {
            for threads in [1usize, 2, 4] {
                let mut y = Mat::from_fn(33, 7, |i, j| (i * 7 + j) as f64);
                a.spmm_cols_into(&x, &mut y, j0, j1, threads);
                for i in 0..33 {
                    for j in 0..7 {
                        let want = if (j0..j1).contains(&j) {
                            full[(i, j)]
                        } else {
                            (i * 7 + j) as f64 // untouched
                        };
                        assert_eq!(y[(i, j)], want, "({i},{j}) win {j0}..{j1}");
                    }
                }
            }
        }
    }

    #[test]
    fn windowed_fused_matches_full_kernel_on_the_window() {
        let a = random_square(29, 160, 11);
        let mut rng = Xoshiro256pp::seed_from_u64(12);
        let x = Mat::randn(29, 6, &mut rng);
        let z = Mat::randn(29, 6, &mut rng);
        let mut full = Mat::zeros(29, 6);
        a.spmm_fused(1.3, &x, -0.7, 0.4, &z, &mut full);
        for (j0, j1) in [(0usize, 6usize), (0, 3), (2, 6), (4, 4)] {
            for threads in [1usize, 3] {
                let mut y = Mat::from_fn(29, 6, |i, j| -((i + j) as f64));
                a.spmm_fused_cols_into(1.3, &x, -0.7, 0.4, &z, &mut y, j0, j1, threads);
                for i in 0..29 {
                    for j in 0..6 {
                        let want = if (j0..j1).contains(&j) {
                            full[(i, j)]
                        } else {
                            -((i + j) as f64)
                        };
                        assert_eq!(y[(i, j)], want, "({i},{j}) win {j0}..{j1}");
                    }
                }
            }
        }
    }

    #[test]
    fn f32_spmm_matches_downcast_reference() {
        let a = random_square(23, 110, 13);
        let a32 = CsrMatrixF32::from_f64(&a);
        assert_eq!(a32.nnz(), a.nnz());
        let mut rng = Xoshiro256pp::seed_from_u64(14);
        let x = MatF32::from_f64(&Mat::randn(23, 5, &mut rng));
        // Reference: the same arithmetic done entry by entry in f32.
        let mut want = MatF32::zeros(23, 5);
        for i in 0..23 {
            let (cols, vals) = a32.row(i);
            for t in 0..5 {
                let mut acc = 0.0f32;
                for (c, v) in cols.iter().zip(vals) {
                    acc += v * x.row(*c as usize)[t];
                }
                want.row_mut(i)[t] = acc;
            }
        }
        for threads in [1usize, 2, 7] {
            let mut y = MatF32::zeros(0, 0);
            a32.spmm_into(&x, &mut y, threads);
            // Same accumulation order as the reference loop above.
            assert_eq!(y, want, "threads = {threads}");
        }
    }

    #[test]
    fn f32_fused_threaded_is_bit_for_bit_serial() {
        let a32 = CsrMatrixF32::from_f64(&random_square(31, 140, 15));
        let mut rng = Xoshiro256pp::seed_from_u64(16);
        let x = MatF32::from_f64(&Mat::randn(31, 6, &mut rng));
        let z = MatF32::from_f64(&Mat::randn(31, 6, &mut rng));
        let mut serial = MatF32::zeros(0, 0);
        a32.spmm_fused_into(1.25, &x, -0.5, 0.75, &z, &mut serial, 1);
        for threads in [2usize, 3, 7] {
            let mut y = MatF32::zeros(0, 0);
            a32.spmm_fused_into(1.25, &x, -0.5, 0.75, &z, &mut y, threads);
            assert_eq!(y, serial, "threads = {threads}");
        }
        // Windowed call touches only the window.
        let mut y = MatF32::zeros(31, 6);
        for r in 0..31 {
            y.row_mut(r).fill(7.0);
        }
        a32.spmm_fused_cols_into(1.25, &x, -0.5, 0.75, &z, &mut y, 2, 4, 3);
        for r in 0..31 {
            assert_eq!(y.row(r)[0], 7.0);
            assert_eq!(y.row(r)[5], 7.0);
            assert_eq!(y.row(r)[2], serial.row(r)[2]);
            assert_eq!(y.row(r)[3], serial.row(r)[3]);
        }
    }

    #[test]
    fn norm1_bounds_spectrum() {
        // For symmetric A, spectral radius <= norm1.
        let a = small();
        let d = a.to_dense();
        let eig = crate::linalg::symeig::sym_eig(&d);
        let rho = eig
            .values
            .iter()
            .fold(0.0f64, |m, x| m.max(x.abs()));
        assert!(rho <= a.norm1() + 1e-12);
    }
}
