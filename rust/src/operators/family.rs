//! The open operator-family API: the [`OperatorFamily`] trait and the
//! [`FamilyRegistry`] that resolves config/CLI names to families.
//!
//! The paper's speedup comes from grouping operators with similar
//! eigenvalue distributions (truncated-FFT sort, Algorithm 2) — which
//! matters most on *heterogeneous* datasets mixing several operator
//! families. The original API hard-coded one closed `OperatorKind` enum
//! per run; this module replaces that dispatch with a trait so that
//!
//! 1. every built-in family (Poisson, elliptic, Helmholtz, vibration,
//!    FEM-Helmholtz) is one trait impl next to its assembly code,
//! 2. downstream users can register their own families without touching
//!    this crate, and
//! 3. one [`crate::coordinator::pipeline`] run can generate a
//!    mixed-family dataset (`GenConfig.families`), with the scheduler
//!    keeping similarity runs inside family boundaries.
//!
//! ## Trait contract
//!
//! - [`OperatorFamily::name`] is a stable identifier (manifests, CLI,
//!   config files). It must be non-empty and contain no `:` or
//!   whitespace (the CLI spec syntax `name:count[:grid][:tol]` reserves
//!   them); [`FamilyRegistry::register`] enforces this.
//! - [`OperatorFamily::generate_one`] must be deterministic in
//!   (`opts`, `id`, the RNG stream) and must tag the returned
//!   [`Problem::family`] with exactly [`OperatorFamily::name`] — the
//!   pipeline cross-checks the tag and fails the run on a mismatch.
//! - Every problem a family generates under one [`GenOptions`] must
//!   share one [`SortKeyShape`]: sort keys are only comparable within a
//!   family ([`super::SortKey::try_dist2`] rejects cross-shape
//!   comparisons),
//!   and the scheduler never builds a similarity run that spans two
//!   families.

use super::{GenOptions, Problem, SortKeyShape};
use crate::anyhow;
use crate::rng::Xoshiro256pp;
use crate::sparse::CsrMatrix;
use crate::util::error::Result;
use std::sync::Arc;

/// One operator-eigenvalue dataset family: a named generator of
/// [`Problem`]s with a family-default solve tolerance and a fixed
/// sort-key shape. See the module docs for the full contract.
pub trait OperatorFamily: Send + Sync {
    /// Stable name used in manifests, configs, and CLI flags.
    fn name(&self) -> &str;

    /// The family's default relative-residual solve tolerance (the
    /// paper's per-dataset precision, §D.5). Used when neither the
    /// family spec nor the run config overrides it.
    fn default_tol(&self) -> f64;

    /// Shape of the sort keys this family produces under `opts` — what
    /// the truncated-FFT / greedy sorting compares. All problems of one
    /// family spec share this shape.
    fn sort_key_shape(&self, opts: &GenOptions) -> SortKeyShape;

    /// Generate the problem with dataset index `id` from an explicit
    /// per-problem RNG stream (steps 1–3 of the paper's Figure 1).
    fn generate_one(&self, opts: GenOptions, id: usize, rng: &mut Xoshiro256pp) -> Problem;

    /// The family's consistent mass matrix `M` for the generalized
    /// problem `A x = λ M x`, or `None` when the family's discretization
    /// has no non-identity mass (FDM families: the identity mass is
    /// already folded in, so generalized solves are meaningless there).
    ///
    /// The mass depends only on the grid (never on the sampled
    /// coefficients), so one matrix serves every problem of a family
    /// spec; it must be symmetric positive definite with the same
    /// dimension [`OperatorFamily::generate_one`] produces under `opts`.
    /// The default returns `None`.
    fn mass_matrix(&self, opts: &GenOptions) -> Option<CsrMatrix> {
        let _ = opts;
        None
    }

    /// True when [`OperatorFamily::mass_matrix`] returns a matrix — the
    /// cheap capability probe the CLI's `families` listing and the
    /// pipeline's generalized-mode validation use.
    fn has_mass_matrix(&self) -> bool {
        false
    }
}

/// Name-indexed set of operator families: the five built-ins plus any
/// user-registered ones. Resolution order is registration order;
/// [`FamilyRegistry::names`] is deterministic.
pub struct FamilyRegistry {
    families: Vec<Arc<dyn OperatorFamily>>,
}

impl FamilyRegistry {
    /// An empty registry (no families). Mostly useful in tests; most
    /// callers want [`FamilyRegistry::builtin`].
    pub fn empty() -> Self {
        Self {
            families: Vec::new(),
        }
    }

    /// Registry with the five built-in families registered under their
    /// paper names (`poisson`, `elliptic`, `helmholtz`, `vibration`,
    /// `helmholtz_fem`).
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        for kind in super::OperatorKind::ALL {
            r.register(kind.family_arc())
                .expect("builtin family names are valid and unique");
        }
        r
    }

    /// Register a family. Fails on an empty/reserved-character name or
    /// a name collision (families are never silently replaced).
    pub fn register(&mut self, family: Arc<dyn OperatorFamily>) -> Result<()> {
        let name = family.name().to_string();
        if name.is_empty() {
            return Err(anyhow!("family name must be non-empty"));
        }
        if name.contains(':') || name.contains(char::is_whitespace) {
            return Err(anyhow!(
                "family name {name:?} contains ':' or whitespace (reserved by the \
                 CLI spec syntax name:count[:grid][:tol])"
            ));
        }
        if self.get(&name).is_some() {
            return Err(anyhow!("family {name:?} is already registered"));
        }
        self.families.push(family);
        Ok(())
    }

    /// Look up a family by name.
    pub fn get(&self, name: &str) -> Option<&Arc<dyn OperatorFamily>> {
        self.families.iter().find(|f| f.name() == name)
    }

    /// Look up a family by name, with an error listing the known names.
    pub fn resolve(&self, name: &str) -> Result<Arc<dyn OperatorFamily>> {
        self.get(name).cloned().ok_or_else(|| {
            anyhow!(
                "unknown operator family {name:?} (registered: {})",
                self.names().join(", ")
            )
        })
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.families.iter().map(|f| f.name()).collect()
    }

    /// Number of registered families.
    pub fn len(&self) -> usize {
        self.families.len()
    }

    /// True if no family is registered.
    pub fn is_empty(&self) -> bool {
        self.families.is_empty()
    }
}

impl Default for FamilyRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}

impl std::fmt::Debug for FamilyRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FamilyRegistry")
            .field("names", &self.names())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::{OperatorKind, SortKey};

    #[test]
    fn builtin_registry_has_all_kinds() {
        let r = FamilyRegistry::builtin();
        assert_eq!(r.len(), OperatorKind::ALL.len());
        for kind in OperatorKind::ALL {
            let f = r.get(kind.name()).expect("registered");
            assert_eq!(f.name(), kind.name());
            assert_eq!(f.default_tol(), kind.default_tol());
        }
    }

    #[test]
    fn builtin_shapes_match_generated_keys() {
        let opts = GenOptions {
            grid: 6,
            ..Default::default()
        };
        let r = FamilyRegistry::builtin();
        for kind in OperatorKind::ALL {
            let f = r.get(kind.name()).unwrap();
            let mut rng = Xoshiro256pp::seed_from_u64(5);
            let p = f.generate_one(opts, 0, &mut rng);
            assert_eq!(p.sort_key.shape(), f.sort_key_shape(&opts), "{}", f.name());
            assert_eq!(p.family.as_ref(), f.name());
        }
    }

    #[test]
    fn duplicate_and_invalid_names_are_rejected() {
        struct Bad(&'static str);
        impl OperatorFamily for Bad {
            fn name(&self) -> &str {
                self.0
            }
            fn default_tol(&self) -> f64 {
                1e-8
            }
            fn sort_key_shape(&self, _opts: &GenOptions) -> SortKeyShape {
                SortKeyShape::Coeffs { len: 1 }
            }
            fn generate_one(
                &self,
                _opts: GenOptions,
                _id: usize,
                _rng: &mut Xoshiro256pp,
            ) -> Problem {
                unreachable!("never generated in this test")
            }
        }
        let mut r = FamilyRegistry::builtin();
        assert!(r.register(Arc::new(Bad("poisson"))).is_err(), "duplicate");
        assert!(r.register(Arc::new(Bad(""))).is_err(), "empty");
        assert!(r.register(Arc::new(Bad("a:b"))).is_err(), "colon");
        assert!(r.register(Arc::new(Bad("a b"))).is_err(), "whitespace");
        assert!(r.register(Arc::new(Bad("fine_name"))).is_ok());
        let err = r.resolve("nope").unwrap_err().to_string();
        assert!(err.contains("unknown operator family"), "{err}");
        assert!(err.contains("poisson"), "error lists known names: {err}");
    }

    #[test]
    fn sort_key_shape_flat_len_matches_keys() {
        let k = SortKey::Coeffs(vec![1.0, 2.0, 3.0]);
        assert_eq!(k.shape(), SortKeyShape::Coeffs { len: 3 });
        assert_eq!(k.shape().flat_len(), 3);
        let f = SortKey::Fields(vec![crate::operators::Field {
            p: 4,
            data: vec![0.0; 16],
        }]);
        assert_eq!(f.shape(), SortKeyShape::Fields { count: 1, p: 4 });
        assert_eq!(f.shape().flat_len(), 16);
    }
}
