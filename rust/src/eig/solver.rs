//! The unified solver interface: one [`EigSolver`] trait over all six
//! [`SolverKind`]s, and the preallocated [`Workspace`] their iteration
//! loops run in.
//!
//! The paper's speedup (and the ROADMAP's "as fast as the hardware
//! allows") lives in the per-iteration cost of filter → QR →
//! Rayleigh–Ritz → residual. Before this refactor every solver
//! re-allocated its block buffers each solve *and* each iteration
//! (`spmm_alloc` in the hot loop); with it, a `Workspace` is prepared
//! once per problem shape and reused across a warm-started sequence —
//! buffers grow monotonically and never shrink, so the steady state of
//! a dataset run is allocation-free inside the solver loops
//! (DESIGN.md §Workspace-architecture).
//!
//! ```no_run
//! use scsf::eig::solver::EigSolver;
//! use scsf::eig::{EigOptions, SolverKind, SpectralOp};
//! # let a = scsf::sparse::CsrMatrix::eye(64);
//! let op = SpectralOp::standard(&a);
//! let solver = SolverKind::Chfsi.instance(&EigOptions::default());
//! let mut ws = solver.prepare(&op);
//! let r1 = solver.solve(&op, &mut ws, None);
//! let warm = r1.as_warm_start();
//! let r2 = solver.solve(&op, &mut ws, Some(&warm)); // zero new blocks
//! ```

use super::chebyshev::{FilterBackendKind, NativeFilter, SellFilter};
use super::chfsi::{self, ChfsiOptions};
use super::op::SpectralOp;
use super::{
    jacobi_davidson, krylov_schur, lanczos, lobpcg, EigOptions, EigResult, SolverKind, WarmStart,
};
use crate::linalg::symeig::SymEig;
use crate::linalg::{Mat, MatF32};
use crate::sparse::CsrMatrix;

/// Preallocated, reusable scratch for one solver instance.
///
/// All buffers grow on demand (via [`Mat::resize`], which keeps the
/// backing allocation) and persist across [`EigSolver::solve`] calls,
/// so a warm-started sequence allocates only on its first problem.
#[derive(Debug)]
pub struct Workspace {
    /// Thread count for the row-partitioned SpMM/SpMV kernels
    /// ([`CsrMatrix::spmm_into`] and friends). Results are bit-for-bit
    /// independent of this value. Set at construction; solver entry
    /// points that carry their own thread knob (`ChfsiOptions::threads`)
    /// overwrite it on entry, so the options stay the source of truth.
    pub threads: usize,
    /// `A·X` product block (`n × k`).
    pub ax: Mat,
    /// General block scratch #1 (filter ping / orthonormal basis).
    pub t1: Mat,
    /// General block scratch #2 (filter pong / correction block).
    pub t2: Mat,
    /// General block scratch #3 (filter third buffer / residual block).
    pub t3: Mat,
    /// General block scratch #4 (LOBPCG frame / rotated iterate).
    pub t4: Mat,
    /// Projected (Gram) matrix scratch (`k × k`).
    pub gram: Mat,
    /// Small dense scratch (Ritz-coefficient slices and the like).
    pub small: Mat,
    /// Reusable symmetric eigendecomposition of the projected problem.
    pub eig: SymEig,
    /// Lanczos basis columns (`m+1` vectors of length `n`).
    pub basis: Vec<Vec<f64>>,
    /// Vector scratch #1 (Lanczos `w`, JD correction).
    pub vec1: Vec<f64>,
    /// Vector scratch #2.
    pub vec2: Vec<f64>,
    /// ChFSI locked basis (`n × L`), populated prefix grows in place as
    /// pairs lock — replaces the per-lock `hcat` reallocation.
    pub locked: Mat,
    /// Adaptive-schedule scratch: Ritz value per active column.
    pub col_theta: Vec<f64>,
    /// Adaptive-schedule scratch: last residual per active column.
    pub col_res: Vec<f64>,
    /// Adaptive-schedule scratch: (degree, column) pairs under sort.
    pub deg_pairs: Vec<(usize, usize)>,
    /// Adaptive-schedule scratch: per-column degrees, sorted descending.
    pub degrees: Vec<usize>,
    /// Adaptive-schedule scratch: column permutation matching `degrees`.
    pub perm: Vec<usize>,
    /// Deflation scratch: columns parked out of the iterate block for
    /// one sweep (`recycling: deflate` only; stays empty under `off`).
    pub defl: Mat,
    /// Mixed-precision scratch: downcast f32 lane of the iterate block.
    pub y32: MatF32,
    /// Mixed-precision scratch: f32 filter output block.
    pub o32: MatF32,
    /// Mixed-precision scratch: f32 filter ping buffer.
    pub ta32: MatF32,
    /// Mixed-precision scratch: f32 filter pong buffer.
    pub tb32: MatF32,
}

impl Workspace {
    /// Empty workspace; buffers grow on first use.
    pub fn new(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
            ax: Mat::zeros(0, 0),
            t1: Mat::zeros(0, 0),
            t2: Mat::zeros(0, 0),
            t3: Mat::zeros(0, 0),
            t4: Mat::zeros(0, 0),
            gram: Mat::zeros(0, 0),
            small: Mat::zeros(0, 0),
            eig: SymEig {
                values: vec![],
                vectors: Mat::zeros(0, 0),
            },
            basis: Vec::new(),
            vec1: Vec::new(),
            vec2: Vec::new(),
            locked: Mat::zeros(0, 0),
            col_theta: Vec::new(),
            col_res: Vec::new(),
            deg_pairs: Vec::new(),
            degrees: Vec::new(),
            perm: Vec::new(),
            defl: Mat::zeros(0, 0),
            y32: MatF32::zeros(0, 0),
            o32: MatF32::zeros(0, 0),
            ta32: MatF32::zeros(0, 0),
            tb32: MatF32::zeros(0, 0),
        }
    }

    /// Pre-size the block buffers for an `n × block` iterate so the
    /// first iteration is already allocation-free.
    pub fn reserve(&mut self, n: usize, block: usize) {
        self.ax.resize(n, block);
        self.t1.resize(n, block);
        self.t2.resize(n, block);
        self.t3.resize(n, block);
        self.t4.resize(n, block);
        self.gram.resize(block, block);
        self.vec1.resize(n, 0.0);
        self.vec2.resize(n, 0.0);
    }

    /// Ensure at least `count` basis vectors of length `n` exist
    /// (Lanczos/Krylov–Schur engine storage), zeroing recycled ones is
    /// the caller's job — the engine overwrites every entry it reads.
    pub fn ensure_basis(&mut self, count: usize, n: usize) {
        for b in &mut self.basis {
            if b.len() != n {
                b.clear();
                b.resize(n, 0.0);
            }
        }
        while self.basis.len() < count {
            self.basis.push(vec![0.0; n]);
        }
    }

    /// Total f64 *capacity* currently held. Stable across same-shape
    /// re-solves (buffers only ever grow), which is what the regression
    /// tests assert. Counts `f64` slots only — the usize-typed adaptive
    /// schedule scratch (`deg_pairs`/`degrees`/`perm`, O(block) each)
    /// and the f32-typed mixed-precision blocks (`y32`/`o32`/`ta32`/
    /// `tb32`; empty unless `precision: mixed`) are deliberately
    /// excluded.
    pub fn capacity_f64(&self) -> usize {
        self.ax.capacity()
            + self.t1.capacity()
            + self.t2.capacity()
            + self.t3.capacity()
            + self.t4.capacity()
            + self.gram.capacity()
            + self.small.capacity()
            + self.eig.vectors.capacity()
            + self.eig.values.capacity()
            + self.basis.iter().map(|b| b.capacity()).sum::<usize>()
            + self.vec1.capacity()
            + self.vec2.capacity()
            + self.locked.capacity()
            + self.col_theta.capacity()
            + self.col_res.capacity()
            + self.defl.capacity()
    }
}

/// The unified solver interface every [`SolverKind`] routes through:
/// size a reusable [`Workspace`] for a problem shape, then solve any
/// number of (same-shaped) problems in it, optionally warm-started.
///
/// Solvers see only the [`SpectralOp`] linear-operator abstraction —
/// plain sparse matrices, generalized pencils and shift-inverted
/// operators all enter through the same interface; warm starts arrive
/// in problem coordinates and are mapped by the engines.
pub trait EigSolver {
    /// Build a workspace sized for `op` (allocation happens here and at
    /// workspace growth, never inside the iteration loops).
    fn prepare(&self, op: &SpectralOp) -> Workspace;

    /// Solve one problem inside `ws`, optionally warm-started from a
    /// previous, similar problem's eigenpairs.
    fn solve(&self, op: &SpectralOp, ws: &mut Workspace, init: Option<&WarmStart>) -> EigResult;

    /// Display label (matches the paper-table column names).
    fn label(&self) -> &'static str;
}

/// Concrete [`EigSolver`] for any [`SolverKind`], carrying the solver
/// options. Construct via [`SolverKind::instance`].
#[derive(Debug, Clone, Copy)]
pub struct Solver {
    kind: SolverKind,
    opts: ChfsiOptions,
}

impl Solver {
    /// New instance from base options (ChFSI/SCSF take the paper-default
    /// filter parameters; use [`Solver::with_chfsi`] to override them).
    pub fn new(kind: SolverKind, opts: &EigOptions) -> Self {
        Self {
            kind,
            opts: ChfsiOptions::from_eig(opts),
        }
    }

    /// New instance with explicit ChFSI options (degree, guard, threads).
    pub fn with_chfsi(kind: SolverKind, opts: ChfsiOptions) -> Self {
        Self { kind, opts }
    }

    /// The solver kind this instance dispatches to.
    pub fn kind(&self) -> SolverKind {
        self.kind
    }

    /// Iterate-block width this instance will use on an `n`-dimensional
    /// problem (wanted pairs + guard vectors, clamped to the dimension —
    /// honours a custom `ChfsiOptions::guard`).
    pub fn block_width(&self, n: usize) -> usize {
        self.opts.block_width(n)
    }
}

impl EigSolver for Solver {
    fn prepare(&self, op: &SpectralOp) -> Workspace {
        let mut ws = Workspace::new(self.opts.threads);
        ws.reserve(op.n(), self.block_width(op.n()));
        ws
    }

    fn solve(&self, op: &SpectralOp, ws: &mut Workspace, init: Option<&WarmStart>) -> EigResult {
        match self.kind {
            SolverKind::Eigsh => lanczos::solve_op_in(op, &self.opts.eig, init, ws),
            SolverKind::Lobpcg => lobpcg::solve_op_in(op, &self.opts.eig, init, ws),
            SolverKind::KrylovSchur => krylov_schur::solve_op_in(op, &self.opts.eig, init, ws),
            SolverKind::JacobiDavidson => {
                jacobi_davidson::solve_op_in(op, &self.opts.eig, init, ws)
            }
            SolverKind::Chfsi | SolverKind::Scsf => match self.opts.filter_backend {
                FilterBackendKind::Csr => {
                    let mut backend = NativeFilter::new();
                    chfsi::solve_op_in(op, &self.opts, init, &mut backend, ws)
                }
                FilterBackendKind::Sell => {
                    let mut backend = SellFilter::new();
                    chfsi::solve_op_in(op, &self.opts, init, &mut backend, ws)
                }
            },
        }
    }

    fn label(&self) -> &'static str {
        self.kind.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::{self, GenOptions, OperatorKind};

    fn problem(grid: usize, seed: u64) -> CsrMatrix {
        operators::generate(
            OperatorKind::Helmholtz,
            GenOptions {
                grid,
                ..Default::default()
            },
            1,
            seed,
        )
        .remove(0)
        .matrix
    }

    #[test]
    fn trait_solve_matches_kind_solve_for_all_kinds() {
        let a = problem(9, 1);
        let opts = EigOptions {
            n_eigs: 4,
            tol: 1e-8,
            max_iters: 600,
            seed: 0,
        };
        for kind in [
            SolverKind::Eigsh,
            SolverKind::Lobpcg,
            SolverKind::KrylovSchur,
            SolverKind::JacobiDavidson,
            SolverKind::Chfsi,
            SolverKind::Scsf,
        ] {
            let direct = kind.solve(&a, &opts, None);
            let solver = kind.instance(&opts);
            let op = SpectralOp::standard(&a);
            let mut ws = solver.prepare(&op);
            let via_trait = solver.solve(&op, &mut ws, None);
            assert_eq!(direct.values, via_trait.values, "{kind:?}");
            assert_eq!(direct.vectors, via_trait.vectors, "{kind:?}");
        }
    }

    #[test]
    fn workspace_stops_growing_after_first_solve() {
        let a = problem(10, 2);
        let opts = EigOptions {
            n_eigs: 5,
            tol: 1e-8,
            max_iters: 400,
            seed: 1,
        };
        for kind in [SolverKind::Chfsi, SolverKind::Eigsh, SolverKind::Lobpcg] {
            let solver = kind.instance(&opts);
            let op = SpectralOp::standard(&a);
            let mut ws = solver.prepare(&op);
            let r = solver.solve(&op, &mut ws, None);
            let cap_after_first = ws.capacity_f64();
            let warm = r.as_warm_start();
            let _ = solver.solve(&op, &mut ws, Some(&warm));
            assert_eq!(
                ws.capacity_f64(),
                cap_after_first,
                "{kind:?} workspace grew on a same-shape re-solve"
            );
        }
    }

    #[test]
    fn reserve_and_basis_are_idempotent() {
        let mut ws = Workspace::new(0);
        assert_eq!(ws.threads, 1);
        ws.reserve(50, 8);
        let cap = ws.capacity_f64();
        ws.reserve(50, 8);
        assert_eq!(ws.capacity_f64(), cap);
        ws.ensure_basis(5, 50);
        assert_eq!(ws.basis.len(), 5);
        ws.ensure_basis(3, 50);
        assert_eq!(ws.basis.len(), 5);
        ws.ensure_basis(5, 20);
        assert!(ws.basis.iter().all(|b| b.len() == 20));
    }
}
