//! Greedy nearest-neighbour ordering on flat keys — the expensive
//! baseline sort of SKR (Wang et al. 2024) and the second stage of the
//! truncated-FFT sort (Algorithm 2, lines 5–9).

use crate::operators::{Problem, SortKey};

/// Flatten a problem's raw parameter data into one vector (the
/// uncompressed Frobenius key used by the plain greedy sort).
pub fn raw_key(p: &Problem) -> Vec<f64> {
    match &p.sort_key {
        SortKey::Fields(fields) => {
            let mut out = Vec::new();
            for f in fields {
                out.extend_from_slice(&f.data);
            }
            out
        }
        SortKey::Coeffs(c) => c.clone(),
    }
}

/// Greedy chain: start at the first problem, repeatedly append the
/// nearest unvisited problem (squared Euclidean distance on keys).
/// `O(N²·d)` where `d` is the key length.
pub fn greedy_order(keys: &[Vec<f64>]) -> Vec<usize> {
    let n = keys.len();
    if n == 0 {
        return vec![];
    }
    let d2 = |a: &[f64], b: &[f64]| -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let mut s = 0.0;
        for i in 0..a.len() {
            let t = a[i] - b[i];
            s += t * t;
        }
        s
    };
    let mut visited = vec![false; n];
    let mut order = Vec::with_capacity(n);
    let mut cur = 0usize;
    visited[0] = true;
    order.push(0);
    for _ in 1..n {
        let mut best = usize::MAX;
        let mut best_d = f64::INFINITY;
        for (cand, key) in keys.iter().enumerate() {
            if !visited[cand] {
                let dd = d2(&keys[cur], key);
                if dd < best_d {
                    best_d = dd;
                    best = cand;
                }
            }
        }
        visited[best] = true;
        order.push(best);
        cur = best;
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_scalars_monotonically() {
        // 1-D keys starting from keys[0]: greedy walks to the nearest
        // each step, which for a line of points yields a sorted walk.
        let keys: Vec<Vec<f64>> = vec![
            vec![5.0],
            vec![1.0],
            vec![9.0],
            vec![4.0],
            vec![6.0],
        ];
        let order = greedy_order(&keys);
        assert_eq!(order[0], 0);
        // From 5: nearest is 4, then 6; from 6 the nearest remaining is 9
        // (distance 3) before 1 (distance 5).
        assert_eq!(order, vec![0, 3, 4, 2, 1]);
    }

    #[test]
    fn empty_and_single() {
        assert!(greedy_order(&[]).is_empty());
        assert_eq!(greedy_order(&[vec![1.0]]), vec![0]);
    }

    #[test]
    fn permutation_property() {
        let keys: Vec<Vec<f64>> = (0..20)
            .map(|i| vec![(i * 7 % 13) as f64, (i * 3 % 5) as f64])
            .collect();
        let mut order = greedy_order(&keys);
        order.sort_unstable();
        assert_eq!(order, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn chain_cost_not_worse_than_identity_on_clusters() {
        // Two tight clusters: greedy must visit one cluster fully before
        // jumping to the other (identity order alternates → higher cost).
        let mut keys = Vec::new();
        for i in 0..4 {
            keys.push(vec![i as f64 * 0.01]); // cluster A near 0
            keys.push(vec![100.0 + i as f64 * 0.01]); // cluster B near 100
        }
        let order = greedy_order(&keys);
        let cost = |ord: &[usize]| -> f64 {
            ord.windows(2)
                .map(|w| (keys[w[0]][0] - keys[w[1]][0]).abs())
                .sum()
        };
        let identity: Vec<usize> = (0..keys.len()).collect();
        assert!(cost(&order) < cost(&identity) / 3.0);
        // Exactly one long jump between clusters.
        let jumps = order
            .windows(2)
            .filter(|w| (keys[w[0]][0] - keys[w[1]][0]).abs() > 50.0)
            .count();
        assert_eq!(jumps, 1);
    }
}
