//! End-to-end tests of the fault-tolerant solve supervision layer:
//! every injectable fault class, against every built-in operator
//! family, through the full two-shard pipeline.
//!
//! Three properties are demanded. (1) A faulted record never takes the
//! run down: the dataset completes with the injected record carrying
//! the documented `status`/`fault` pair. (2) A fault poisons only its
//! own record: with the faulted record placed at the tail of its warm
//! chain, every other record is byte-identical to a clean run's.
//! (3) Crash-resume works across a dataset containing quarantined
//! records, reproducing the faulted run bit for bit.

use scsf::coordinator::config::{FamilySpec, GenConfig};
use scsf::coordinator::dataset::{DatasetReader, RecordMeta};
use scsf::coordinator::pipeline::{generate_dataset, resume_dataset};
use scsf::eig::op::Transform;
use scsf::eig::scsf::SolveStatus;
use scsf::sort::SortMethod;
use scsf::testing::faults::{Fault, FaultPlan};
use std::path::{Path, PathBuf};

/// The five built-in operator families.
const FAMILIES: [&str; 5] = [
    "poisson",
    "elliptic",
    "helmholtz",
    "vibration",
    "helmholtz_fem",
];

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "scsf_fault_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Small-but-real two-shard config (the supervision layer must work
/// across concurrent runs, not just a single chain).
fn base_cfg(family: &str) -> GenConfig {
    GenConfig {
        families: vec![FamilySpec::new(family, 6)],
        grid: 8,
        n_eigs: 3,
        tol: Some(1e-7),
        seed: 23,
        shards: 2,
        channel_capacity: 2,
        sort: SortMethod::TruncatedFft { p0: 6 },
        ..Default::default()
    }
}

/// A record's exact byte span in `eigs.bin`.
fn record_bytes<'a>(bin: &'a [u8], meta: &RecordMeta) -> &'a [u8] {
    let len = 3 * 8 + meta.l * 8 + meta.n * meta.l * 8;
    &bin[meta.offset as usize..meta.offset as usize + len]
}

/// Strip the fields two otherwise-identical runs may legitimately
/// disagree on: `offset` depends on nondeterministic arrival
/// interleave, `secs` on the clock.
fn normalized(meta: &RecordMeta) -> RecordMeta {
    let mut m = meta.clone();
    m.offset = 0;
    m.secs = 0.0;
    m
}

fn meta_of(reader: &DatasetReader, id: usize) -> RecordMeta {
    reader
        .index()
        .iter()
        .find(|r| r.id == id)
        .unwrap_or_else(|| panic!("record {id} missing from the manifest"))
        .clone()
}

/// Fault classes that run on the plain (untransformed) operator:
/// a worker panic quarantines its record; one forced non-convergence
/// climbs the ladder and lands `retried`; an unbounded forced
/// non-convergence exhausts the iterative rungs and is rescued by the
/// dense fallback (small plain operators only).
#[test]
fn plain_fault_matrix_covers_every_family() {
    for family in FAMILIES {
        let dir = tmpdir(&format!("plain_{family}"));
        let mut cfg = base_cfg(family);
        cfg.fault_injection = Some(FaultPlan {
            records: vec![
                (1, Fault::NonConvergence { times: 1 }),
                (3, Fault::Panic),
                (5, Fault::NonConvergence { times: 99 }),
            ],
        });
        let report = generate_dataset(&cfg, &dir).unwrap();
        assert_eq!(report.n_problems, 6, "{family}");
        assert_eq!(report.quarantined, 1, "{family}: {:?}", report.faults);
        assert_eq!(report.faults.get("panic"), Some(&1), "{family}");
        assert!(report.retries >= 1, "{family}: {report:?}");
        assert!(report.fallbacks >= 1, "{family}: {report:?}");

        let reader = DatasetReader::open(&dir).unwrap();
        assert_eq!(reader.index().len(), 6, "{family}");

        let retried = meta_of(&reader, 1);
        assert_eq!(retried.status, SolveStatus::Retried, "{family}");
        assert!(retried.retries >= 1, "{family}");
        assert!(retried.fault.is_empty(), "{family}: {}", retried.fault);
        assert!(retried.l > 0, "{family}");

        let panicked = meta_of(&reader, 3);
        assert_eq!(panicked.status, SolveStatus::Quarantined, "{family}");
        assert_eq!(panicked.fault, "panic", "{family}");
        assert_eq!(panicked.l, 0, "{family}");

        let rescued = meta_of(&reader, 5);
        assert_eq!(rescued.status, SolveStatus::Retried, "{family}");
        assert!(rescued.fallback, "{family}: dense fallback must rescue");
        assert!(rescued.l > 0, "{family}");

        for rec in reader.index().iter().filter(|r| ![1, 3, 5].contains(&r.id)) {
            assert_ne!(
                rec.status,
                SolveStatus::Quarantined,
                "{family}: record {} must be untouched",
                rec.id
            );
            assert!(rec.l > 0, "{family}: record {}", rec.id);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Fault classes that need a factorization in the loop (shift-invert):
/// an injected pivot breakdown is recovered by the bounded diagonal
/// perturbation (`retried`, fault `factorization`); a non-convergence
/// that outlasts the ladder quarantines, because transformed operators
/// have no dense fallback rung.
#[test]
fn factorization_fault_matrix_covers_every_family() {
    for family in FAMILIES {
        let dir = tmpdir(&format!("factor_{family}"));
        let mut cfg = base_cfg(family);
        cfg.transform = Transform::ShiftInvert { sigma: 0.0 };
        cfg.fault_injection = Some(FaultPlan {
            records: vec![
                (2, Fault::PivotBreakdown),
                (4, Fault::NonConvergence { times: 99 }),
            ],
        });
        let report = generate_dataset(&cfg, &dir).unwrap();
        assert_eq!(report.n_problems, 6, "{family}");
        assert_eq!(report.quarantined, 1, "{family}: {:?}", report.faults);
        assert_eq!(report.faults.get("factorization"), Some(&1), "{family}");
        assert_eq!(report.faults.get("nonconvergence"), Some(&1), "{family}");

        let reader = DatasetReader::open(&dir).unwrap();
        assert_eq!(reader.index().len(), 6, "{family}");

        let recovered = meta_of(&reader, 2);
        assert_eq!(recovered.status, SolveStatus::Retried, "{family}");
        assert_eq!(recovered.fault, "factorization", "{family}");
        assert!(recovered.l > 0, "{family}");

        let exhausted = meta_of(&reader, 4);
        assert_eq!(exhausted.status, SolveStatus::Quarantined, "{family}");
        assert_eq!(exhausted.fault, "nonconvergence", "{family}");
        assert_eq!(exhausted.l, 0, "{family}");

        for rec in reader.index().iter().filter(|r| ![2, 4].contains(&r.id)) {
            assert_ne!(
                rec.status,
                SolveStatus::Quarantined,
                "{family}: record {} must be untouched",
                rec.id
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// The stall class: with the watchdog armed, a stalled record is
/// abandoned after the timeout and quarantined with fault `timeout`;
/// every other record still solves (on the watchdog's per-record
/// supervised threads).
#[test]
fn stall_fault_matrix_covers_every_family() {
    for family in FAMILIES {
        let dir = tmpdir(&format!("stall_{family}"));
        let mut cfg = base_cfg(family);
        cfg.solve_timeout_secs = Some(2.0);
        cfg.fault_injection = Some(FaultPlan::single(0, Fault::Stall { secs: 30.0 }));
        let report = generate_dataset(&cfg, &dir).unwrap();
        assert_eq!(report.quarantined, 1, "{family}: {:?}", report.faults);
        assert_eq!(report.faults.get("timeout"), Some(&1), "{family}");

        let reader = DatasetReader::open(&dir).unwrap();
        assert_eq!(reader.index().len(), 6, "{family}");
        let stalled = meta_of(&reader, 0);
        assert_eq!(stalled.status, SolveStatus::Quarantined, "{family}");
        assert_eq!(stalled.fault, "timeout", "{family}");
        assert_eq!(stalled.l, 0, "{family}");
        for rec in reader.index().iter().filter(|r| r.id != 0) {
            assert_eq!(
                rec.status,
                SolveStatus::Ok,
                "{family}: record {} must solve cleanly under the watchdog",
                rec.id
            );
            assert!(rec.l > 0, "{family}: record {}", rec.id);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A fault poisons only its own record. The victim is the record its
/// shard solves *last* (per-sender FIFO through the result channel
/// means the shard's max-offset record closes its solve order), so
/// quarantining it perturbs no downstream solve in either warm chain —
/// every other record must be byte-identical to the clean run's.
#[test]
fn panic_on_a_chain_tail_leaves_every_other_record_byte_identical() {
    let d_clean = tmpdir("bytes_clean");
    let d_fault = tmpdir("bytes_fault");
    let cfg = base_cfg("helmholtz");
    generate_dataset(&cfg, &d_clean).unwrap();
    let clean = DatasetReader::open(&d_clean).unwrap();
    let clean_index = clean.index().to_vec();
    let victim = clean_index
        .iter()
        .filter(|r| r.shard == 0)
        .max_by_key(|r| r.offset)
        .unwrap()
        .id;
    let mut fcfg = cfg.clone();
    fcfg.fault_injection = Some(FaultPlan::single(victim, Fault::Panic));
    let report = generate_dataset(&fcfg, &d_fault).unwrap();
    assert_eq!(report.quarantined, 1);
    let faulted = DatasetReader::open(&d_fault).unwrap();
    let bin_clean = std::fs::read(d_clean.join("eigs.bin")).unwrap();
    let bin_fault = std::fs::read(d_fault.join("eigs.bin")).unwrap();
    for rc in clean_index.iter().filter(|r| r.id != victim) {
        let rf = meta_of(&faulted, rc.id);
        assert_eq!(normalized(rc), normalized(&rf), "id {}", rc.id);
        assert_eq!(
            record_bytes(&bin_clean, rc),
            record_bytes(&bin_fault, &rf),
            "id {}: record bytes must match the clean run",
            rc.id
        );
    }
    let q = meta_of(&faulted, victim);
    assert_eq!(q.status, SolveStatus::Quarantined);
    assert_eq!(q.fault, "panic");
    assert_eq!(q.l, 0);
    let _ = std::fs::remove_dir_all(&d_clean);
    let _ = std::fs::remove_dir_all(&d_fault);
}

fn copy_dataset(src: &Path, dst: &Path) {
    let _ = std::fs::remove_dir_all(dst);
    std::fs::create_dir_all(dst).unwrap();
    for f in ["eigs.bin", "manifest.json"] {
        std::fs::copy(src.join(f), dst.join(f)).unwrap();
    }
}

/// Crash-resume across a dataset that already contains a quarantined
/// record. The manifest is torn *after* the quarantine's checkpoint
/// (fault plans are never serialized, so a resumed re-solve of the
/// faulted record would succeed and fork the dataset); resume must
/// skip the quarantined record, re-enter the chain cold after it, and
/// reproduce the uninterrupted faulted run bit for bit.
#[test]
fn resume_crosses_a_quarantined_record() {
    let d_full = tmpdir("resq_full");
    let d_torn = tmpdir("resq_torn");
    let mut cfg = base_cfg("helmholtz");
    cfg.chunk_records = Some(2);
    cfg.fault_injection = Some(FaultPlan::single(0, Fault::Panic));
    let report = generate_dataset(&cfg, &d_full).unwrap();
    assert_eq!(report.quarantined, 1);

    let full = DatasetReader::open(&d_full).unwrap();
    let full_index = full.index().to_vec();
    let layout = full.layout().expect("chunked dataset has a layout").clone();
    // Cut the manifest at the start of the chunk after the one holding
    // the quarantined record — the quarantine stays checkpointed, the
    // tail must be re-solved. When the quarantine sits in the last
    // chunk, tear only the footer instead (everything checkpointed).
    let qpos = full_index
        .iter()
        .position(|r| r.status == SolveStatus::Quarantined)
        .expect("one record is quarantined");
    let chunk_idx = layout
        .chunks
        .iter()
        .position(|c| qpos < c.first_record + c.records)
        .unwrap();
    let manifest = d_torn.join("manifest.json");
    copy_dataset(&d_full, &d_torn);
    let cut = match layout.chunks.get(chunk_idx + 1) {
        Some(next) => next.manifest_offset,
        None => std::fs::metadata(&manifest).unwrap().len() - 1,
    };
    let f = std::fs::OpenOptions::new()
        .write(true)
        .open(&manifest)
        .unwrap();
    f.set_len(cut).unwrap();
    drop(f);

    let resumed_report = resume_dataset(&d_torn).unwrap();
    assert_eq!(resumed_report.n_problems, 6);
    assert!(resumed_report.resumed_records >= 1);
    // The checkpointed quarantine folds back into the resumed rollups.
    assert_eq!(resumed_report.quarantined, 1, "{:?}", resumed_report.faults);
    assert_eq!(resumed_report.faults.get("panic"), Some(&1));

    let resumed = DatasetReader::open(&d_torn).unwrap();
    assert!(resumed.layout().unwrap().complete);
    assert_eq!(resumed.index().len(), 6);
    let bin_full = std::fs::read(d_full.join("eigs.bin")).unwrap();
    let bin_res = std::fs::read(d_torn.join("eigs.bin")).unwrap();
    for rf in &full_index {
        let rr = meta_of(&resumed, rf.id);
        assert_eq!(normalized(rf), normalized(&rr), "id {}", rf.id);
        assert_eq!(
            record_bytes(&bin_full, rf),
            record_bytes(&bin_res, &rr),
            "id {}: resumed record bytes must match the uninterrupted run",
            rf.id
        );
    }
    let _ = std::fs::remove_dir_all(&d_full);
    let _ = std::fs::remove_dir_all(&d_torn);
}
