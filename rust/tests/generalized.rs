//! Integration tests for the operator-abstraction refactor (ISSUE 9):
//! generalized eigenproblems `Ax = λMx`, shift-invert spectral
//! transforms for interior windows, the bit-for-bit default regression
//! across every operator family, XLA / incompatible-knob rejection at
//! config resolution, and the legacy-manifest read-back contract for
//! the new `factor_secs` / `trisolve_count` counters.

use scsf::coordinator::config::GenConfig;
use scsf::coordinator::dataset::DatasetReader;
use scsf::coordinator::pipeline::{generate_dataset, generate_problems};
use scsf::eig::chfsi::{self, ChfsiOptions};
use scsf::eig::op::{ProblemKind, SpectralOp, Transform};
use scsf::eig::EigOptions;
use scsf::linalg::symeig::{sym_eig, sym_eig_generalized};
use scsf::operators::{self, FamilyRegistry, GenOptions, OperatorKind};
use scsf::sparse::CsrMatrix;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("scsf_genrl_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// The family's mass matrix through the [`OperatorFamily`] hook — the
/// same path the pipeline's producer uses.
fn mass_of(kind: OperatorKind, grid: usize) -> CsrMatrix {
    let opts = GenOptions {
        grid,
        ..Default::default()
    };
    let reg = FamilyRegistry::builtin();
    let fam = reg.get(kind.name()).expect("builtin family");
    fam.mass_matrix(&opts).expect("family carries a mass matrix")
}

/// σ in the widest spectral gap among indices `lo..hi` of `dense`
/// (ascending): interior by construction and safely away from both
/// neighbours. Returns `(σ, first wanted index)` — the solver's window
/// under shift-invert is the eigenvalues just above σ.
fn interior_shift(dense: &[f64], lo: usize, hi: usize) -> (f64, usize) {
    let mut best = lo;
    for g in lo..hi {
        if dense[g + 1] - dense[g] > dense[best + 1] - dense[best] {
            best = g;
        }
    }
    (0.5 * (dense[best] + dense[best + 1]), best + 1)
}

/// Property: on both mass-carrying families the generalized solve
/// matches the dense `Ax = λMx` oracle, meets tolerance in the B-norm
/// residual the engine reports, and returns an M-orthonormal basis
/// (the W-transform's coordinate contract).
#[test]
fn generalized_matches_dense_oracle_on_mass_families() {
    for kind in [OperatorKind::Vibration, OperatorKind::HelmholtzFem] {
        let grid = 8;
        let tol = kind.default_tol();
        let problems = operators::generate(
            kind,
            GenOptions {
                grid,
                ..Default::default()
            },
            2,
            13,
        );
        let m = mass_of(kind, grid);
        let l = 4;
        let mut opts = ChfsiOptions::from_eig(&EigOptions {
            n_eigs: l,
            tol,
            max_iters: 600,
            seed: 0,
        });
        opts.problem = ProblemKind::Generalized;
        for p in &problems {
            let op = SpectralOp::build(&p.matrix, Some(&m), opts.problem, opts.transform);
            let op = op.unwrap();
            let r = chfsi::solve_op(&op, &opts, None);
            assert!(r.stats.converged, "{kind:?}: {:?}", r.residuals);
            for res in &r.residuals {
                assert!(*res <= tol, "{kind:?}: residual {res} > {tol}");
            }
            assert!(r.stats.trisolve_count > 0, "{kind:?}: no trisolves counted");
            let want = sym_eig_generalized(&p.matrix.to_dense(), &m.to_dense());
            for (got, w) in r.values.iter().zip(&want.values[..l]) {
                assert!(
                    (got - w).abs() / w.abs().max(1.0) < 1e-6,
                    "{kind:?}: {got} vs dense {w}"
                );
            }
            // VᵀMV = I: back-transformed vectors are M-orthonormal.
            let n = p.matrix.rows();
            let mut xj = vec![0.0; n];
            let mut mx = vec![0.0; n];
            for j in 0..l {
                for i in 0..n {
                    xj[i] = r.vectors[(i, j)];
                }
                m.spmv_into(&xj, &mut mx, 1);
                for c in 0..l {
                    let mut dot = 0.0;
                    for i in 0..n {
                        dot += r.vectors[(i, c)] * mx[i];
                    }
                    let want = if c == j { 1.0 } else { 0.0 };
                    assert!(
                        (dot - want).abs() < 1e-7,
                        "{kind:?}: (VᵀMV)[{c},{j}] = {dot}, want {want}"
                    );
                }
            }
        }
    }
}

/// The acceptance property: shift-invert on Helmholtz converges every
/// wanted pair of an interior window (σ in a spectral gap, window = the
/// eigenvalues just above σ) to residual ≤ tol against the dense
/// oracle, with the transform counters populated.
#[test]
fn shift_invert_converges_interior_helmholtz_windows() {
    let problems = operators::generate(
        OperatorKind::Helmholtz,
        GenOptions {
            grid: 10,
            ..Default::default()
        },
        3,
        17,
    );
    let tol = 1e-9;
    for p in &problems {
        let dense = sym_eig(&p.matrix.to_dense()).values;
        let (sigma, first) = interior_shift(&dense, 3, 8);
        let mut opts = ChfsiOptions::from_eig(&EigOptions {
            n_eigs: 4,
            tol,
            max_iters: 400,
            seed: 0,
        });
        opts.transform = Transform::ShiftInvert { sigma };
        let r = chfsi::solve(&p.matrix, &opts, None);
        assert!(r.stats.converged, "window at σ={sigma}: {:?}", r.residuals);
        for res in &r.residuals {
            assert!(*res <= tol, "residual {res} > {tol}");
        }
        for (got, want) in r.values.iter().zip(&dense[first..first + 4]) {
            assert!(
                (got - want).abs() / want.abs().max(1.0) < 1e-7,
                "window at σ={sigma}: {got} vs dense {want}"
            );
        }
        assert!(r.stats.trisolve_count > 0, "no triangular solves counted");
        assert!(r.stats.factor_secs > 0.0, "factorization time not recorded");
    }
}

/// Generalized + shift-invert combined: an interior window of the
/// vibration pencil `Kx = λMx`, checked against the dense generalized
/// oracle.
#[test]
fn generalized_shift_invert_targets_interior_pencil_window() {
    let grid = 8;
    let p = operators::generate(
        OperatorKind::Vibration,
        GenOptions {
            grid,
            ..Default::default()
        },
        1,
        23,
    )
    .remove(0);
    let m = mass_of(OperatorKind::Vibration, grid);
    let dense = sym_eig_generalized(&p.matrix.to_dense(), &m.to_dense()).values;
    let (sigma, first) = interior_shift(&dense, 3, 8);
    let tol = 1e-8;
    let mut opts = ChfsiOptions::from_eig(&EigOptions {
        n_eigs: 4,
        tol,
        max_iters: 600,
        seed: 0,
    });
    opts.problem = ProblemKind::Generalized;
    opts.transform = Transform::ShiftInvert { sigma };
    let op = SpectralOp::build(&p.matrix, Some(&m), opts.problem, opts.transform).unwrap();
    let r = chfsi::solve_op(&op, &opts, None);
    assert!(r.stats.converged, "σ={sigma}: {:?}", r.residuals);
    for res in &r.residuals {
        assert!(*res <= tol, "residual {res} > {tol}");
    }
    for (got, want) in r.values.iter().zip(&dense[first..first + 4]) {
        assert!(
            (got - want).abs() / want.abs().max(1.0) < 1e-6,
            "σ={sigma}: {got} vs dense {want}"
        );
    }
    assert!(r.stats.trisolve_count > 0);
}

/// The new knobs are rejected by name wherever they cannot run: the
/// XLA backend (no generalized or spectral-transformation path),
/// mass-less families under `problem: generalized`, and the
/// mixed-precision / deflation combinations that are coordinate-bound
/// to plain operators.
#[test]
fn incompatible_operator_mode_knobs_are_rejected_at_resolution() {
    let reg = FamilyRegistry::builtin();
    let xla = r#"{
        "families": [{"family": "vibration", "count": 2}],
        "grid": 8, "n_eigs": 4, "tol": 1e-8, "seed": 1,
        "backend": {"kind": "xla", "artifacts_dir": "/nonexistent"},
        "sort": {"method": "truncated_fft", "p0": 6}
    }"#;
    let resolve_err = |json: &str| -> String {
        GenConfig::from_json(json)
            .unwrap()
            .resolve(&reg)
            .unwrap_err()
            .to_string()
    };
    fn ins(json: &str, key: &str) -> String {
        json.replace("\"grid\": 8,", &format!("\"grid\": 8, {key},"))
    }

    let err = resolve_err(&ins(xla, "\"problem\": \"generalized\""));
    assert!(err.contains("problem"), "unexpected error: {err}");
    assert!(err.contains("native backend"), "unexpected error: {err}");
    let err = resolve_err(&ins(xla, "\"transform\": \"shift_invert:1.5\""));
    assert!(err.contains("transform"), "unexpected error: {err}");
    assert!(err.contains("native backend"), "unexpected error: {err}");

    let native = xla.replace(
        "\"backend\": {\"kind\": \"xla\", \"artifacts_dir\": \"/nonexistent\"},",
        "",
    );
    // Generalized needs a mass matrix; poisson provides none.
    let massless = native.replace("\"vibration\"", "\"poisson\"");
    let err = resolve_err(&ins(&massless, "\"problem\": \"generalized\""));
    assert!(err.contains("mass matrix"), "unexpected error: {err}");
    // Transformed operators reject mixed precision and deflation.
    let knobs = "\"problem\": \"generalized\", \"precision\": \"mixed\"";
    let err = resolve_err(&ins(&native, knobs));
    assert!(err.contains("precision"), "unexpected error: {err}");
    let knobs = "\"transform\": \"shift_invert:2.0\", \"recycling\": \"deflate\"";
    let err = resolve_err(&ins(&native, knobs));
    assert!(err.contains("recycling"), "unexpected error: {err}");

    // Unknown values hard-error at parse time.
    let bad = ins(&native, "\"problem\": \"general\"");
    assert!(GenConfig::from_json(&bad).is_err());
    let bad = ins(&native, "\"transform\": \"shift_invert:nan\"");
    assert!(GenConfig::from_json(&bad).is_err());
    let bad = ins(&native, "\"transform\": \"cayley\"");
    assert!(GenConfig::from_json(&bad).is_err());
}

/// Bit-for-bit regression: a config that never mentions the new knobs
/// and one that pins the defaults (`problem: standard`, `transform:
/// none`) must produce byte-identical `eigs.bin` files, identical
/// record indexes, and identical config echoes — across all five
/// built-in families, including the mixed-precision and SELL-backend
/// variants. The manifest must not grow any new keys.
#[test]
fn standard_defaults_are_bit_identical_with_explicit_mode_keys() {
    for (tag, extra) in [
        ("default", ""),
        ("mixed", "\"precision\": \"mixed\","),
        ("sell", "\"filter_backend\": \"sell\","),
    ] {
        let d_legacy = tmpdir(&format!("legacy_{tag}"));
        let d_explicit = tmpdir(&format!("explicit_{tag}"));
        let fam_json: Vec<String> = OperatorKind::ALL
            .iter()
            .map(|k| format!("{{\"family\": \"{}\", \"count\": 2}}", k.name()))
            .collect();
        let legacy_json = format!(
            r#"{{
            "families": [{}],
            "grid": 8, "n_eigs": 4, "tol": 1e-8, "seed": 11, {}
            "shards": 2, "channel_capacity": 2,
            "sort": {{"method": "truncated_fft", "p0": 6}}
        }}"#,
            fam_json.join(", "),
            extra
        );
        let explicit_json = legacy_json.replace(
            "\"grid\": 8,",
            "\"grid\": 8, \"problem\": \"standard\", \"transform\": \"none\",",
        );
        let cfg_legacy = GenConfig::from_json(&legacy_json).unwrap();
        let cfg_explicit = GenConfig::from_json(&explicit_json).unwrap();
        assert_eq!(cfg_explicit.problem, ProblemKind::Standard);
        assert!(cfg_explicit.transform.is_none());
        let echo = cfg_legacy.to_json();
        assert_eq!(echo, cfg_explicit.to_json(), "{tag}: config echoes differ");

        generate_dataset(&cfg_legacy, &d_legacy).unwrap();
        generate_dataset(&cfg_explicit, &d_explicit).unwrap();
        let bin1 = std::fs::read(d_legacy.join("eigs.bin")).unwrap();
        let bin2 = std::fs::read(d_explicit.join("eigs.bin")).unwrap();
        assert_eq!(bin1, bin2, "{tag}: eigs.bin must be byte-identical");
        let r1 = DatasetReader::open(&d_legacy).unwrap();
        let r2 = DatasetReader::open(&d_explicit).unwrap();
        assert_eq!(r1.index(), r2.index(), "{tag}: record indexes differ");
        let text = std::fs::read_to_string(d_explicit.join("manifest.json")).unwrap();
        for key in ["\"problem\"", "\"transform\"", "\"factor_secs\"", "\"trisolve_count\""] {
            assert!(!text.contains(key), "{tag}: default manifest grew {key}");
        }
        let _ = std::fs::remove_dir_all(&d_legacy);
        let _ = std::fs::remove_dir_all(&d_explicit);
    }
}

/// Read-back contract: standard datasets (including every pre-refactor
/// dataset, which this run is byte-compatible with) read back zero
/// transform counters, and the manifest never mentions them.
#[test]
fn standard_datasets_read_back_zero_transform_counters() {
    let dir = tmpdir("legacy_readback");
    let cfg = GenConfig::from_json(
        r#"{
        "families": [{"family": "helmholtz", "count": 3}],
        "grid": 8, "n_eigs": 4, "tol": 1e-8, "seed": 3,
        "shards": 2, "channel_capacity": 2,
        "sort": {"method": "truncated_fft", "p0": 6}
    }"#,
    )
    .unwrap();
    let report = generate_dataset(&cfg, &dir).unwrap();
    assert_eq!(report.trisolve_count, 0);
    assert_eq!(report.factor_secs, 0.0);
    let reader = DatasetReader::open(&dir).unwrap();
    assert!(reader
        .index()
        .iter()
        .all(|r| r.trisolve_count == 0 && r.factor_secs == 0.0));
    let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    assert!(!text.contains("\"factor_secs\""));
    assert!(!text.contains("\"trisolve_count\""));
    let _ = std::fs::remove_dir_all(&dir);
}

/// End-to-end generalized run through the pipeline: the producer
/// attaches the family mass matrices, every record converges, the
/// read-back eigenvalues match the dense pencil oracle, and the
/// transform counters surface in records, report rollups, and the
/// manifest config echo.
#[test]
fn generalized_pipeline_matches_dense_pencil_oracle() {
    let dir = tmpdir("gen_pipeline");
    let cfg = GenConfig::from_json(
        r#"{
        "families": [{"family": "vibration", "count": 2},
                     {"family": "helmholtz_fem", "count": 2}],
        "grid": 8, "n_eigs": 4, "tol": 1e-8, "seed": 5,
        "shards": 2, "channel_capacity": 2,
        "problem": "generalized",
        "sort": {"method": "truncated_fft", "p0": 6}
    }"#,
    )
    .unwrap();
    let problems = generate_problems(&cfg);
    assert!(
        problems.iter().all(|p| p.mass.is_some()),
        "producer must attach mass matrices under problem: generalized"
    );
    let report = generate_dataset(&cfg, &dir).unwrap();
    assert!(report.trisolve_count > 0, "report rollup lost trisolves");
    assert!(report.factor_secs > 0.0, "report rollup lost factor time");
    let mut reader = DatasetReader::open(&dir).unwrap();
    assert_eq!(reader.index().len(), 4);
    let metas: Vec<_> = reader.index().to_vec();
    for meta in &metas {
        assert!(meta.max_residual <= 1e-8, "record {}: {}", meta.id, meta.max_residual);
        assert!(meta.trisolve_count > 0, "record {} counted no trisolves", meta.id);
    }
    for p in &problems {
        let rec = reader.read(p.id).unwrap();
        let m = p.mass.as_ref().unwrap();
        let want = sym_eig_generalized(&p.matrix.to_dense(), &m.to_dense());
        for (got, w) in rec.values.iter().zip(&want.values[..rec.values.len()]) {
            assert!(
                (got - w).abs() / w.abs().max(1.0) < 1e-6,
                "record {}: {got} vs dense {w}",
                p.id
            );
        }
    }
    let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    let v = scsf::util::json::parse(&text).unwrap();
    assert_eq!(
        v.get("config")
            .and_then(|c| c.get("problem"))
            .and_then(scsf::util::json::Value::as_str),
        Some("generalized")
    );
    assert!(text.contains("\"trisolve_count\""));
    let _ = std::fs::remove_dir_all(&dir);
}

/// End-to-end shift-invert run through the pipeline: the dataset's
/// records carry the interior window above σ and the per-record /
/// rollup counters are populated. σ is derived from the standard twin
/// of the same config — `generate_problems` replays the producer
/// exactly, so the matrices agree.
#[test]
fn shift_invert_pipeline_emits_interior_window_and_counters() {
    let dir = tmpdir("shift_pipeline");
    let mut cfg = GenConfig::from_json(
        r#"{
        "families": [{"family": "helmholtz", "count": 1}],
        "grid": 8, "n_eigs": 4, "tol": 1e-8, "seed": 7,
        "shards": 1, "channel_capacity": 2,
        "sort": {"method": "truncated_fft", "p0": 6}
    }"#,
    )
    .unwrap();
    let p = generate_problems(&cfg).remove(0);
    let dense = sym_eig(&p.matrix.to_dense()).values;
    let (sigma, first) = interior_shift(&dense, 3, 8);
    cfg.transform = Transform::ShiftInvert { sigma };
    let report = generate_dataset(&cfg, &dir).unwrap();
    assert!(report.trisolve_count > 0);
    assert!(report.factor_secs > 0.0);
    let mut reader = DatasetReader::open(&dir).unwrap();
    let meta = reader.index()[0].clone();
    assert!(meta.trisolve_count > 0);
    assert!(meta.factor_secs > 0.0);
    assert!(meta.max_residual <= 1e-8);
    let rec = reader.read(0).unwrap();
    for (got, want) in rec.values.iter().zip(&dense[first..first + 4]) {
        assert!(
            (got - want).abs() / want.abs().max(1.0) < 1e-6,
            "σ={sigma}: {got} vs dense {want}"
        );
    }
    let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    assert!(text.contains("shift_invert"));
    assert!(text.contains("\"factor_secs\""));
    let _ = std::fs::remove_dir_all(&dir);
}
