//! The Chebyshev filter (paper Algorithm 1) and the pluggable backend
//! abstraction that lets the filter run either natively (sparse SpMM in
//! rust) or through the AOT-compiled JAX/Pallas kernel via PJRT
//! ([`crate::runtime::filter_exec`]).
//!
//! The filter applies the scaled-and-shifted degree-`m` Chebyshev
//! polynomial `p_m(A)` to a block `Y`, where `p_m` maps the *unwanted*
//! spectral interval `[α, β]` to `[-1, 1]` (so those components are
//! damped, `|C_m| ≤ 1`) and grows super-exponentially below `α` (so the
//! wanted smallest eigenvalues are amplified — paper Figure 2(f)).
//! The σ-scaling normalizes `p_m` at the target eigenvalue `λ` to avoid
//! overflow (Zhou et al. 2006).

use crate::eig::op::SpectralOp;
use crate::linalg::{flops, Mat, MatF32};
use crate::sparse::{CsrMatrix, CsrMatrixF32, SellMatrix, SellMatrixF32};

/// How the ChFSI loop spends polynomial degree across the iterate
/// block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FilterSchedule {
    /// Every column gets the full configured degree every sweep — the
    /// paper's Algorithm 1, bit-for-bit identical to the historical
    /// output.
    #[default]
    Fixed,
    /// Convergence-aware scheduling: each active column is assigned its
    /// own degree from its residual and the filter's per-degree
    /// amplification on the current interval ([`required_degree`]),
    /// columns are sorted by assigned degree, and the three-term
    /// recurrence runs over a shrinking column window
    /// ([`chebyshev_filter_window_into`]). Deterministic, but *not*
    /// bit-for-bit equal to [`FilterSchedule::Fixed`].
    Adaptive,
}

impl FilterSchedule {
    /// Config/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            FilterSchedule::Fixed => "fixed",
            FilterSchedule::Adaptive => "adaptive",
        }
    }

    /// Parse a config/CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fixed" => Some(FilterSchedule::Fixed),
            "adaptive" => Some(FilterSchedule::Adaptive),
            _ => None,
        }
    }
}

/// Arithmetic precision of the Chebyshev filter sweeps.
///
/// Only the filter's SpMM chain ever leaves f64: the Rayleigh–Ritz
/// projection, residual evaluation, and locking always run in f64, so
/// both settings accept a Ritz pair only when its **f64** relative
/// residual is ≤ tol.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Everything in f64 — bit-for-bit identical to the historical
    /// output (the default).
    #[default]
    F64,
    /// Filter sweeps run in f32 while a column's residual is above its
    /// [`f32_promotion_floor`]; the column is promoted back to f64 for
    /// the endgame. Same accuracy guarantee, not bit-for-bit equal to
    /// [`Precision::F64`].
    Mixed,
}

impl Precision {
    /// Config/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::Mixed => "mixed",
        }
    }

    /// Parse a config/CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f64" => Some(Precision::F64),
            "mixed" => Some(Precision::Mixed),
            _ => None,
        }
    }
}

/// Sparse-matrix layout used by the native filter backends.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FilterBackendKind {
    /// Row-partitioned CSR ([`NativeFilter`]) — the historical kernel,
    /// bit-for-bit identical to every prior release (the default).
    #[default]
    Csr,
    /// SELL-C-σ sliced layout ([`SellFilter`]): fixed-width lane loops
    /// over C = [`crate::sparse::SELL_CHUNK`] rows with per-slice nnz
    /// padding. Deterministic for any thread count, but its per-row
    /// accumulation order differs from CSR, so it is *not* bit-for-bit
    /// equal to [`FilterBackendKind::Csr`].
    Sell,
}

impl FilterBackendKind {
    /// Config/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            FilterBackendKind::Csr => "csr",
            FilterBackendKind::Sell => "sell",
        }
    }

    /// Parse a config/CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "csr" => Some(FilterBackendKind::Csr),
            "sell" => Some(FilterBackendKind::Sell),
            _ => None,
        }
    }
}

/// Smallest degree the adaptive schedule assigns to an active column.
/// Near-converged columns still take a short filter pass so their Ritz
/// pair keeps improving instead of stalling at the tolerance edge.
pub const MIN_ADAPTIVE_DEGREE: usize = 2;

/// Parameters of one filter application.
#[derive(Debug, Clone, Copy)]
pub struct FilterParams {
    /// Polynomial degree `m` (paper default 20).
    pub degree: usize,
    /// Lower edge `α` of the damped (unwanted) interval.
    pub lower: f64,
    /// Upper edge `β` of the damped interval (≥ λ_max, from
    /// [`crate::eig::spectral_bounds`]).
    pub upper: f64,
    /// Normalization point `λ` — an estimate of the smallest wanted
    /// eigenvalue (paper: `λ ≈ λ'_1` of the previous problem).
    pub target: f64,
}

impl FilterParams {
    /// Interval center `c = (α+β)/2`.
    #[inline]
    pub fn center(&self) -> f64 {
        0.5 * (self.lower + self.upper)
    }

    /// Interval half-width `e = (β−α)/2`.
    #[inline]
    pub fn half_width(&self) -> f64 {
        0.5 * (self.upper - self.lower)
    }

    /// Clamp into a numerically safe configuration: `target < α < β`.
    pub fn sanitized(mut self) -> Self {
        if !(self.upper > self.lower) {
            self.upper = self.lower + self.lower.abs().max(1.0) * 1e-3;
        }
        let width = self.upper - self.lower;
        if !(self.target < self.lower) {
            self.target = self.lower - 1e-3 * width;
        }
        self
    }

    /// Scalar filter value `p_m(t)` — the reference implementation used
    /// by tests and by the python oracle cross-check.
    pub fn eval_scalar(&self, t: f64) -> f64 {
        let p = self.sanitized();
        let c = p.center();
        let e = p.half_width();
        let mut sigma = e / (p.target - c);
        let sigma1 = sigma;
        let mut ym = (t - c) / e * sigma1;
        let mut ymm = 1.0;
        for _ in 1..p.degree {
            let sigma_new = 1.0 / (2.0 / sigma1 - sigma);
            let y = 2.0 * ((t - c) / e) * sigma_new * ym - sigma * sigma_new * ymm;
            ymm = ym;
            ym = y;
            sigma = sigma_new;
        }
        ym
    }
}

/// Where the filter's block products are executed.
///
/// Every entry point takes the solve's [`SpectralOp`]; backends with
/// specialized kernels (CSR/SELL/f32, the XLA route) dispatch on
/// [`SpectralOp::plain`] — `Some(A)` recovers the historical layout and
/// bit-for-bit arithmetic, `None` (generalized / shift-invert modes)
/// routes through the operator-generic recurrence.
pub trait FilterBackend {
    /// Called once at the start of every eigensolve with the operator
    /// that all subsequent `filter*` calls will use. Backends that
    /// cache a derived representation of `A` (the f32 downcast, the
    /// SELL repack) invalidate it here; chained solves reuse the same
    /// backend across problems with identical sparsity but different
    /// values, so skipping this hook would silently filter with a stale
    /// operator. The default does nothing.
    fn begin_solve(&mut self, _op: &SpectralOp) {}

    /// Apply the degree-`m` filter to `y`, returning the filtered block.
    fn filter(&mut self, op: &SpectralOp, y: &Mat, params: &FilterParams) -> Mat;

    /// Zero-alloc variant: write the filtered block into `out`, using
    /// `tmp1`/`tmp2` as the recurrence's other two ping-pong buffers and
    /// `threads` row-partitioned threads for the SpMM. The default
    /// implementation routes through [`FilterBackend::filter`] (the
    /// XLA path allocates host literals anyway); the native backend
    /// overrides it with the true in-place recurrence.
    #[allow(clippy::too_many_arguments)]
    fn filter_into(
        &mut self,
        op: &SpectralOp,
        y: &Mat,
        params: &FilterParams,
        out: &mut Mat,
        tmp1: &mut Mat,
        tmp2: &mut Mat,
        threads: usize,
    ) {
        let _ = (tmp1, tmp2, threads);
        let r = self.filter(op, y, params);
        out.copy_from(&r);
    }

    /// Schedule-aware variant: filter column `j` of `y` to degree
    /// `degrees[j]` (sorted descending), writing the block into `out`.
    /// Returns the total applied degree (the filter's matvec count).
    /// The default implementation ignores the schedule and filters the
    /// whole block at the maximum degree — correct (extra degree only
    /// amplifies the wanted components further) but without the
    /// matvec savings; the native backend overrides it with the true
    /// shrinking-window recurrence.
    #[allow(clippy::too_many_arguments)]
    fn filter_window_into(
        &mut self,
        op: &SpectralOp,
        y: &Mat,
        params: &FilterParams,
        degrees: &[usize],
        out: &mut Mat,
        tmp1: &mut Mat,
        tmp2: &mut Mat,
        threads: usize,
    ) -> usize {
        let mut p = *params;
        p.degree = degrees.first().copied().unwrap_or(params.degree).max(1);
        self.filter_into(op, y, &p, out, tmp1, tmp2, threads);
        y.cols() * p.degree
    }

    /// f32 sibling of [`FilterBackend::filter_window_into`] for the
    /// mixed-precision path: `y32` holds the not-yet-promoted columns,
    /// the filtered block lands in `out32`. Returns the total applied
    /// degree (the f32 matvec count). The default upcasts, runs the
    /// backend's f64 window filter, and downcasts the result — correct
    /// for every backend (the XLA route keeps working, just without the
    /// f32 speedup); the native backends override it with true f32
    /// kernels. Only ever called with a plain operator (`resolve()`
    /// rejects `precision: mixed` for transformed problems).
    #[allow(clippy::too_many_arguments)]
    fn filter_window_f32_into(
        &mut self,
        op: &SpectralOp,
        y32: &MatF32,
        params: &FilterParams,
        degrees: &[usize],
        out32: &mut MatF32,
        tmp1: &mut MatF32,
        tmp2: &mut MatF32,
        threads: usize,
    ) -> usize {
        let _ = (tmp1, tmp2);
        let y = y32.to_f64();
        let mut out = Mat::zeros(0, 0);
        let (mut t1, mut t2) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
        let applied = self.filter_window_into(op, &y, params, degrees, &mut out, &mut t1, &mut t2, threads);
        out32.downcast_from(&out);
        applied
    }

    /// Diagnostic name (shows up in pipeline metrics).
    fn name(&self) -> &'static str;

    /// `(accelerated_calls, native_fallbacks)` counters; the native
    /// backend reports zeros.
    fn counters(&self) -> (usize, usize) {
        (0, 0)
    }
}

/// The native backend: fused CSR SpMM three-term recurrence.
///
/// Holds the one-time f32 downcast of the current solve's operator for
/// the mixed-precision path; [`FilterBackend::begin_solve`] invalidates
/// it, and it is rebuilt lazily on the first f32 window call, so pure
/// f64 solves never pay for it.
#[derive(Debug, Default, Clone)]
pub struct NativeFilter {
    a32: Option<CsrMatrixF32>,
}

impl NativeFilter {
    /// A fresh backend with no cached operator.
    pub fn new() -> Self {
        Self::default()
    }
}

impl FilterBackend for NativeFilter {
    fn begin_solve(&mut self, _op: &SpectralOp) {
        self.a32 = None;
    }

    fn filter(&mut self, op: &SpectralOp, y: &Mat, params: &FilterParams) -> Mat {
        match op.plain() {
            Some(a) => chebyshev_filter(a, y, params),
            None => op_chebyshev_filter(op, y, params),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn filter_into(
        &mut self,
        op: &SpectralOp,
        y: &Mat,
        params: &FilterParams,
        out: &mut Mat,
        tmp1: &mut Mat,
        tmp2: &mut Mat,
        threads: usize,
    ) {
        match op.plain() {
            Some(a) => chebyshev_filter_into(a, y, params, out, tmp1, tmp2, threads),
            None => op_chebyshev_filter_into(op, y, params, out, tmp1, tmp2, threads),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn filter_window_into(
        &mut self,
        op: &SpectralOp,
        y: &Mat,
        params: &FilterParams,
        degrees: &[usize],
        out: &mut Mat,
        tmp1: &mut Mat,
        tmp2: &mut Mat,
        threads: usize,
    ) -> usize {
        match op.plain() {
            Some(a) => chebyshev_filter_window_into(a, y, params, degrees, out, tmp1, tmp2, threads),
            None => op_filter_window_into(op, y, params, degrees, out, tmp1, tmp2, threads),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn filter_window_f32_into(
        &mut self,
        op: &SpectralOp,
        y32: &MatF32,
        params: &FilterParams,
        degrees: &[usize],
        out32: &mut MatF32,
        tmp1: &mut MatF32,
        tmp2: &mut MatF32,
        threads: usize,
    ) -> usize {
        let a = op
            .plain()
            .expect("mixed-precision filtering requires a plain (untransformed) operator");
        let a32 = self.a32.get_or_insert_with(|| CsrMatrixF32::from_f64(a));
        chebyshev_filter_window_f32_into(a32, y32, params, degrees, out32, tmp1, tmp2, threads)
    }

    fn name(&self) -> &'static str {
        "native-csr"
    }
}

/// The SELL-C-σ backend: same three-term recurrence, sliced-ELLPACK
/// SpMM kernels ([`crate::sparse::SellMatrix`]). Both the f64 repack
/// and the f32 downcast are built lazily per solve and invalidated by
/// [`FilterBackend::begin_solve`]. Deterministic for any thread count;
/// not bit-for-bit equal to the CSR backend (different per-row
/// accumulation grouping).
#[derive(Debug, Default, Clone)]
pub struct SellFilter {
    sell: Option<SellMatrix>,
    sell32: Option<SellMatrixF32>,
}

impl SellFilter {
    /// A fresh backend with no cached operator.
    pub fn new() -> Self {
        Self::default()
    }
}

impl FilterBackend for SellFilter {
    fn begin_solve(&mut self, _op: &SpectralOp) {
        self.sell = None;
        self.sell32 = None;
    }

    fn filter(&mut self, op: &SpectralOp, y: &Mat, params: &FilterParams) -> Mat {
        let mut out = Mat::zeros(0, 0);
        let (mut t1, mut t2) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
        self.filter_into(op, y, params, &mut out, &mut t1, &mut t2, 1);
        out
    }

    #[allow(clippy::too_many_arguments)]
    fn filter_into(
        &mut self,
        op: &SpectralOp,
        y: &Mat,
        params: &FilterParams,
        out: &mut Mat,
        tmp1: &mut Mat,
        tmp2: &mut Mat,
        threads: usize,
    ) {
        match op.plain() {
            Some(a) => {
                let sell = self.sell.get_or_insert_with(|| SellMatrix::from_csr(a));
                sell_chebyshev_filter_into(sell, y, params, out, tmp1, tmp2, threads);
            }
            // Transformed operators have no sparse layout to repack —
            // the factor solves dominate; use the generic recurrence.
            None => op_chebyshev_filter_into(op, y, params, out, tmp1, tmp2, threads),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn filter_window_into(
        &mut self,
        op: &SpectralOp,
        y: &Mat,
        params: &FilterParams,
        degrees: &[usize],
        out: &mut Mat,
        tmp1: &mut Mat,
        tmp2: &mut Mat,
        threads: usize,
    ) -> usize {
        match op.plain() {
            Some(a) => {
                let sell = self.sell.get_or_insert_with(|| SellMatrix::from_csr(a));
                sell_filter_window_into(sell, y, params, degrees, out, tmp1, tmp2, threads)
            }
            None => op_filter_window_into(op, y, params, degrees, out, tmp1, tmp2, threads),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn filter_window_f32_into(
        &mut self,
        op: &SpectralOp,
        y32: &MatF32,
        params: &FilterParams,
        degrees: &[usize],
        out32: &mut MatF32,
        tmp1: &mut MatF32,
        tmp2: &mut MatF32,
        threads: usize,
    ) -> usize {
        let a = op
            .plain()
            .expect("mixed-precision filtering requires a plain (untransformed) operator");
        let sell32 = self.sell32.get_or_insert_with(|| SellMatrixF32::from_csr(a));
        sell_filter_window_f32_into(sell32, y32, params, degrees, out32, tmp1, tmp2, threads)
    }

    fn name(&self) -> &'static str {
        "native-sell"
    }
}

/// Apply the Chebyshev filter (Algorithm 1) with the fused SpMM kernel.
///
/// Recurrence (all applied to the whole block):
/// ```text
/// Y₁   = (σ₁/e)·(A − cI)·Y₀
/// Yᵢ₊₁ = 2(σᵢ₊₁/e)·(A − cI)·Yᵢ − σᵢσᵢ₊₁·Yᵢ₋₁
/// ```
pub fn chebyshev_filter(a: &CsrMatrix, y0: &Mat, params: &FilterParams) -> Mat {
    let mut out = Mat::zeros(0, 0);
    let mut tmp1 = Mat::zeros(0, 0);
    let mut tmp2 = Mat::zeros(0, 0);
    chebyshev_filter_into(a, y0, params, &mut out, &mut tmp1, &mut tmp2, 1);
    out
}

/// Zero-alloc Chebyshev filter: the three-term recurrence runs entirely
/// inside the caller-provided buffers (`out` receives the result,
/// `tmp1`/`tmp2` are the other two ping-pong blocks), with the SpMM
/// row-partitioned over `threads` threads. Arithmetic is identical to
/// [`chebyshev_filter`] for every thread count (the threaded kernel is
/// bit-for-bit deterministic), which is what keeps warm-started
/// sequences reproducible across machine configurations.
#[allow(clippy::too_many_arguments)]
pub fn chebyshev_filter_into(
    a: &CsrMatrix,
    y0: &Mat,
    params: &FilterParams,
    out: &mut Mat,
    tmp1: &mut Mat,
    tmp2: &mut Mat,
    threads: usize,
) {
    let p = params.sanitized();
    assert!(p.degree >= 1, "filter degree must be ≥ 1");
    let c = p.center();
    let e = p.half_width();
    let sigma1 = e / (p.target - c);
    let mut sigma = sigma1;

    // Y1 = (σ1/e) (A − cI) Y0; tmp1 plays Y0 (= Y_prev) for step 2.
    tmp1.copy_from(y0);
    a.spmm_fused_into(sigma1 / e, y0, -c * sigma1 / e, 0.0, y0, out, threads);

    for _i in 1..p.degree {
        let sigma_new = 1.0 / (2.0 / sigma1 - sigma);
        // Y⁺ = (2σ⁺/e)(A − cI) Y − σσ⁺ Y⁻  (Y = out, Y⁻ = tmp1 → tmp2)
        a.spmm_fused_into(
            2.0 * sigma_new / e,
            out,
            -2.0 * c * sigma_new / e,
            -sigma * sigma_new,
            tmp1,
            tmp2,
            threads,
        );
        // Rotate buffer *contents* (O(1) Vec swaps): prev ← cur, then
        // cur ← next, so `out` always names the newest iterate.
        std::mem::swap(tmp1, out);
        std::mem::swap(out, tmp2);
        sigma = sigma_new;
    }
}

/// Degree the adaptive schedule assigns to one active column: the
/// smallest `m` whose filter pass is expected to push the column's
/// relative residual below `goal`, clamped to `[MIN_ADAPTIVE_DEGREE,
/// cap]`.
///
/// The ChASE-style estimate: a Ritz pair at `θ` (below the damped
/// interval `[α, β]`) has its unwanted error components shrunk per
/// degree by `ρ(θ) = g + √(g² − 1)` with `g = (c − θ)/e`, because
/// `|C_m(g)| ≈ ½ ρ(θ)^m` while `|C_m| ≤ 1` on `[α, β]`. Solving
/// `2 · residual · ρ^{−m} ≤ goal` for `m` gives the schedule. Columns
/// sitting inside the damped interval (`g ≤ 1` — e.g. the block-edge
/// guard) or with unknown residuals get the cap.
///
/// The `goal` is the caller's per-column accuracy target for this
/// sweep — `0.5·tol` for wanted columns in the endgame, lifted by the
/// block's leakage floor ([`predicted_residual`] of the worst wanted
/// column) while convergence is still bulk, and relaxed to
/// [`guard_target`] for guard columns.
pub fn required_degree(
    residual: f64,
    goal: f64,
    theta: f64,
    params: &FilterParams,
    cap: usize,
) -> usize {
    let cap = cap.max(1);
    let min_deg = MIN_ADAPTIVE_DEGREE.min(cap);
    let p = params.sanitized();
    let g = (p.center() - theta) / p.half_width();
    if !(g > 1.0) || !residual.is_finite() || !(goal > 0.0) {
        return cap;
    }
    let rho = g + (g * g - 1.0).sqrt();
    let need = 2.0 * residual / goal;
    if need <= 1.0 {
        return min_deg;
    }
    let m = (need.ln() / rho.ln()).ceil();
    if !m.is_finite() || m >= cap as f64 {
        return cap;
    }
    (m as usize).clamp(min_deg, cap)
}

/// Predicted relative residual of a column after one cap-degree filter
/// pass: `2·r·ρ(θ)^{−cap}` (∞ for columns the filter cannot damp —
/// unknown residual or `θ` inside the damped interval). The maximum of
/// this over the *wanted* columns is the block's leakage floor: the
/// Rayleigh–Ritz step mixes columns, so aiming any column far below
/// what the worst wanted column can reach this sweep is wasted degree.
pub fn predicted_residual(residual: f64, theta: f64, params: &FilterParams, cap: usize) -> f64 {
    let p = params.sanitized();
    let g = (p.center() - theta) / p.half_width();
    if !(g > 1.0) || !residual.is_finite() {
        return f64::INFINITY;
    }
    let rho = g + (g * g - 1.0).sqrt();
    2.0 * residual * rho.powi(-(cap.min(i32::MAX as usize) as i32))
}

/// Accuracy target for guard columns under the adaptive schedule:
/// `10·√tol`. Guards never lock — they only stabilize the
/// Rayleigh–Ritz step and absorb filter leakage around the spectral
/// cut — so carrying them to the full tolerance is wasted degree;
/// half the digits is enough to keep the wanted prefix converging at
/// full speed (validated across all operator families by
/// `rust/tests/adaptive_filter.rs` and the `filter_degree` bench).
pub fn guard_target(tol: f64) -> f64 {
    10.0 * tol.abs().sqrt()
}

/// Relative-residual floor below which f32 filter sweeps stop helping a
/// Ritz pair and the mixed-precision path promotes its column back to
/// f64: `max(tol, √n·ε₃₂·κ_j)` with `κ_j = max(1, β/max(1, |θ_j|))`.
///
/// The rationale: a single f32 SpMM perturbs `A·x` by roughly
/// `√n·ε₃₂·‖A‖·‖x‖` (random-sign accumulation over ~n-length dot
/// products — the deterministic `n·ε₃₂` bound is far too pessimistic
/// for the ~5–13-nnz stencil rows here), so the *relative* residual
/// `‖Av − θv‖ / |θ|` of a column at Ritz value `θ_j` cannot be driven
/// reliably below `√n·ε₃₂·‖A‖/|θ_j|` by f32 arithmetic. `β` (the
/// damped interval's upper edge, ≥ λ_max from the solver's spectral
/// bounds) stands in for `‖A‖`. Clamping below by `tol` means a loose
/// tolerance keeps everything in f32 to the finish; a tight tolerance
/// hands the endgame to f64. Correctness never depends on this value —
/// acceptance is gated by the f64 residual check — it only decides
/// where the cheap sweeps stop paying off.
pub fn f32_promotion_floor(tol: f64, n: usize, upper: f64, theta: f64) -> f64 {
    let eps32 = f32::EPSILON as f64;
    let kappa = (upper.abs() / theta.abs().max(1.0)).max(1.0);
    tol.max((n as f64).sqrt().max(8.0) * eps32 * kappa)
}

/// Shrinking-window Chebyshev filter: column `j` of `y0` is filtered to
/// degree `degrees[j]` (the per-column schedule, sorted **descending**),
/// all inside the same three rotating buffers as
/// [`chebyshev_filter_into`]. A column drops out of the fused SpMM the
/// step its degree is reached — no copies, no compaction; the window is
/// a prefix sub-slice of the row-major blocks
/// ([`CsrMatrix::spmm_fused_cols_into`]). Returns the total applied
/// degree `Σ degrees[j]`, i.e. the filter's matvec count.
///
/// Retired columns stay put in whichever physical buffer held the
/// newest iterate at their retirement step; the buffers rotate names
/// with period 3 (`out → tmp1 → tmp2 → out`), so after the final step
/// `M` a column retired at step `s` sits in `out` when `(M − s) % 3 ==
/// 0`, in `tmp1` when `1`, in `tmp2` when `2` — the single end-of-run
/// gather copies each retired range into `out` exactly once.
///
/// A uniform schedule (`degrees[j] == m` for all `j`) reproduces
/// [`chebyshev_filter_into`] at degree `m` bit for bit; a mixed
/// schedule gives each column exactly the standalone degree-`m_j`
/// filter (the σ sequence depends on the step index only).
#[allow(clippy::too_many_arguments)]
pub fn chebyshev_filter_window_into(
    a: &CsrMatrix,
    y0: &Mat,
    params: &FilterParams,
    degrees: &[usize],
    out: &mut Mat,
    tmp1: &mut Mat,
    tmp2: &mut Mat,
    threads: usize,
) -> usize {
    window_driver_f64(
        a.rows(),
        y0,
        params,
        degrees,
        out,
        tmp1,
        tmp2,
        &mut |ca, x, cb, cc, z, y, j0, j1| a.spmm_fused_cols_into(ca, x, cb, cc, z, y, j0, j1, threads),
    )
}

/// SELL-C-σ layout sibling of [`chebyshev_filter_window_into`]: the
/// identical shrinking-window recurrence (same driver, same coefficient
/// sequence) with the fused products dispatched to
/// [`SellMatrix::spmm_fused_cols_into`].
#[allow(clippy::too_many_arguments)]
pub fn sell_filter_window_into(
    a: &SellMatrix,
    y0: &Mat,
    params: &FilterParams,
    degrees: &[usize],
    out: &mut Mat,
    tmp1: &mut Mat,
    tmp2: &mut Mat,
    threads: usize,
) -> usize {
    window_driver_f64(
        a.rows(),
        y0,
        params,
        degrees,
        out,
        tmp1,
        tmp2,
        &mut |ca, x, cb, cc, z, y, j0, j1| a.spmm_fused_cols_into(ca, x, cb, cc, z, y, j0, j1, threads),
    )
}

/// f32 shrinking-window filter over the downcast operator. The σ
/// coefficient sequence is computed in f64 (it is a scalar recurrence —
/// keeping it in f64 costs nothing and avoids compounding rounding into
/// the coefficients) and rounded to f32 only at each kernel call.
#[allow(clippy::too_many_arguments)]
pub fn chebyshev_filter_window_f32_into(
    a: &CsrMatrixF32,
    y0: &MatF32,
    params: &FilterParams,
    degrees: &[usize],
    out: &mut MatF32,
    tmp1: &mut MatF32,
    tmp2: &mut MatF32,
    threads: usize,
) -> usize {
    window_driver_f32(
        a.rows(),
        y0,
        params,
        degrees,
        out,
        tmp1,
        tmp2,
        &mut |ca, x, cb, cc, z, y, j0, j1| {
            a.spmm_fused_cols_into(ca as f32, x, cb as f32, cc as f32, z, y, j0, j1, threads)
        },
    )
}

/// f32 shrinking-window filter over the SELL-C-σ downcast operator.
#[allow(clippy::too_many_arguments)]
pub fn sell_filter_window_f32_into(
    a: &SellMatrixF32,
    y0: &MatF32,
    params: &FilterParams,
    degrees: &[usize],
    out: &mut MatF32,
    tmp1: &mut MatF32,
    tmp2: &mut MatF32,
    threads: usize,
) -> usize {
    window_driver_f32(
        a.rows(),
        y0,
        params,
        degrees,
        out,
        tmp1,
        tmp2,
        &mut |ca, x, cb, cc, z, y, j0, j1| {
            a.spmm_fused_cols_into(ca as f32, x, cb as f32, cc as f32, z, y, j0, j1, threads)
        },
    )
}

/// Full-block Chebyshev filter over the SELL-C-σ layout — the
/// [`chebyshev_filter_into`] recurrence with the fused products
/// dispatched to [`SellMatrix::spmm_fused_into`].
#[allow(clippy::too_many_arguments)]
pub fn sell_chebyshev_filter_into(
    a: &SellMatrix,
    y0: &Mat,
    params: &FilterParams,
    out: &mut Mat,
    tmp1: &mut Mat,
    tmp2: &mut Mat,
    threads: usize,
) {
    let p = params.sanitized();
    assert!(p.degree >= 1, "filter degree must be ≥ 1");
    let c = p.center();
    let e = p.half_width();
    let sigma1 = e / (p.target - c);
    let mut sigma = sigma1;

    tmp1.copy_from(y0);
    a.spmm_fused_into(sigma1 / e, y0, -c * sigma1 / e, 0.0, y0, out, threads);

    for _i in 1..p.degree {
        let sigma_new = 1.0 / (2.0 / sigma1 - sigma);
        a.spmm_fused_into(
            2.0 * sigma_new / e,
            out,
            -2.0 * c * sigma_new / e,
            -sigma * sigma_new,
            tmp1,
            tmp2,
            threads,
        );
        std::mem::swap(tmp1, out);
        std::mem::swap(out, tmp2);
        sigma = sigma_new;
    }
}

/// The engine shared by every f64 window filter: the three-term
/// recurrence, shrinking-window bookkeeping, and end-of-run gather,
/// parameterized over the fused SpMM kernel so the CSR and SELL
/// backends cannot drift arithmetically. `fused(a, x, b, c, z, y, j0,
/// j1)` must compute `y[:, j0..j1] = a·A·x + b·x + c·z` column-window.
#[allow(clippy::too_many_arguments)]
fn window_driver_f64(
    n: usize,
    y0: &Mat,
    params: &FilterParams,
    degrees: &[usize],
    out: &mut Mat,
    tmp1: &mut Mat,
    tmp2: &mut Mat,
    fused: &mut dyn FnMut(f64, &Mat, f64, f64, &Mat, &mut Mat, usize, usize),
) -> usize {
    let k = y0.cols();
    assert_eq!(degrees.len(), k, "one degree per column");
    // Correctness-critical: the shrinking window is a prefix, so an
    // unsorted schedule would retire the wrong columns. O(k) check vs
    // O(nnz·k·m) of work — always on.
    assert!(
        degrees.windows(2).all(|w| w[0] >= w[1]),
        "degrees must be sorted descending"
    );
    if k == 0 {
        out.set_shape(n, 0);
        return 0;
    }
    assert!(*degrees.last().unwrap() >= 1, "filter degree must be ≥ 1");
    let p = params.sanitized();
    let max_deg = degrees[0];
    let c = p.center();
    let e = p.half_width();
    let sigma1 = e / (p.target - c);
    let mut sigma = sigma1;

    // Y1 = (σ1/e) (A − cI) Y0 over the whole block; tmp1 keeps Y0.
    tmp1.copy_from(y0);
    out.set_shape(n, k);
    tmp2.set_shape(n, k);
    fused(sigma1 / e, y0, -c * sigma1 / e, 0.0, y0, out, 0, k);

    // Retirement bookkeeping: (step, j0, j1) column ranges that reached
    // their degree, in retirement order.
    let mut retired: Vec<(usize, usize, usize)> = Vec::new();
    let mut w = degrees.partition_point(|&d| d >= 2);
    if w < k {
        retired.push((1, w, k));
    }
    let mut s = 1usize;
    while s < max_deg {
        let sigma_new = 1.0 / (2.0 / sigma1 - sigma);
        // Y⁺ = (2σ⁺/e)(A − cI) Y − σσ⁺ Y⁻ over the active window only.
        fused(
            2.0 * sigma_new / e,
            out,
            -2.0 * c * sigma_new / e,
            -sigma * sigma_new,
            tmp1,
            tmp2,
            0,
            w,
        );
        std::mem::swap(tmp1, out);
        std::mem::swap(out, tmp2);
        sigma = sigma_new;
        s += 1;
        let w_next = degrees.partition_point(|&d| d >= s + 1);
        if w_next < w {
            retired.push((s, w_next, w));
        }
        w = w_next;
    }
    for &(step, j0, j1) in &retired {
        match (max_deg - step) % 3 {
            0 => {} // already in `out`
            1 => out.copy_cols_from(tmp1, j0, j1),
            _ => out.copy_cols_from(tmp2, j0, j1),
        }
    }
    degrees.iter().sum()
}

/// f32 twin of [`window_driver_f64`] — the same recurrence over
/// [`MatF32`] buffers. Coefficients arrive in f64; the kernel closure
/// rounds them to f32 at the call boundary.
#[allow(clippy::too_many_arguments)]
fn window_driver_f32(
    n: usize,
    y0: &MatF32,
    params: &FilterParams,
    degrees: &[usize],
    out: &mut MatF32,
    tmp1: &mut MatF32,
    tmp2: &mut MatF32,
    fused: &mut dyn FnMut(f64, &MatF32, f64, f64, &MatF32, &mut MatF32, usize, usize),
) -> usize {
    let k = y0.cols();
    assert_eq!(degrees.len(), k, "one degree per column");
    assert!(
        degrees.windows(2).all(|w| w[0] >= w[1]),
        "degrees must be sorted descending"
    );
    if k == 0 {
        out.set_shape(n, 0);
        return 0;
    }
    assert!(*degrees.last().unwrap() >= 1, "filter degree must be ≥ 1");
    let p = params.sanitized();
    let max_deg = degrees[0];
    let c = p.center();
    let e = p.half_width();
    let sigma1 = e / (p.target - c);
    let mut sigma = sigma1;

    tmp1.copy_from(y0);
    out.set_shape(n, k);
    tmp2.set_shape(n, k);
    fused(sigma1 / e, y0, -c * sigma1 / e, 0.0, y0, out, 0, k);

    let mut retired: Vec<(usize, usize, usize)> = Vec::new();
    let mut w = degrees.partition_point(|&d| d >= 2);
    if w < k {
        retired.push((1, w, k));
    }
    let mut s = 1usize;
    while s < max_deg {
        let sigma_new = 1.0 / (2.0 / sigma1 - sigma);
        fused(
            2.0 * sigma_new / e,
            out,
            -2.0 * c * sigma_new / e,
            -sigma * sigma_new,
            tmp1,
            tmp2,
            0,
            w,
        );
        std::mem::swap(tmp1, out);
        std::mem::swap(out, tmp2);
        sigma = sigma_new;
        s += 1;
        let w_next = degrees.partition_point(|&d| d >= s + 1);
        if w_next < w {
            retired.push((s, w_next, w));
        }
        w = w_next;
    }
    for &(step, j0, j1) in &retired {
        match (max_deg - step) % 3 {
            0 => {}
            1 => out.copy_cols_from(tmp1, j0, j1),
            _ => out.copy_cols_from(tmp2, j0, j1),
        }
    }
    degrees.iter().sum()
}

/// Operator-generic Chebyshev filter: [`chebyshev_filter`] with the
/// fused products dispatched through [`SpectralOp::apply_fused_cols_into`]
/// — the path generalized and shift-inverted solves take (for a plain
/// op it reproduces the CSR kernel arithmetic, but backends dispatch to
/// the specialized kernels before reaching here).
pub fn op_chebyshev_filter(op: &SpectralOp, y0: &Mat, params: &FilterParams) -> Mat {
    let mut out = Mat::zeros(0, 0);
    let (mut t1, mut t2) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
    op_chebyshev_filter_into(op, y0, params, &mut out, &mut t1, &mut t2, 1);
    out
}

/// Zero-alloc operator-generic filter — the [`chebyshev_filter_into`]
/// recurrence over a [`SpectralOp`].
#[allow(clippy::too_many_arguments)]
pub fn op_chebyshev_filter_into(
    op: &SpectralOp,
    y0: &Mat,
    params: &FilterParams,
    out: &mut Mat,
    tmp1: &mut Mat,
    tmp2: &mut Mat,
    threads: usize,
) {
    let p = params.sanitized();
    assert!(p.degree >= 1, "filter degree must be ≥ 1");
    let (n, k) = (op.n(), y0.cols());
    let c = p.center();
    let e = p.half_width();
    let sigma1 = e / (p.target - c);
    let mut sigma = sigma1;

    tmp1.copy_from(y0);
    out.set_shape(n, k);
    tmp2.set_shape(n, k);
    op.apply_fused_cols_into(sigma1 / e, y0, -c * sigma1 / e, 0.0, y0, out, 0, k, threads);

    for _i in 1..p.degree {
        let sigma_new = 1.0 / (2.0 / sigma1 - sigma);
        op.apply_fused_cols_into(
            2.0 * sigma_new / e,
            out,
            -2.0 * c * sigma_new / e,
            -sigma * sigma_new,
            tmp1,
            tmp2,
            0,
            k,
            threads,
        );
        std::mem::swap(tmp1, out);
        std::mem::swap(out, tmp2);
        sigma = sigma_new;
    }
}

/// Operator-generic shrinking-window filter: the exact
/// [`window_driver_f64`] engine of the CSR/SELL paths with the fused
/// products dispatched through the [`SpectralOp`] — the schedule,
/// retirement bookkeeping, and coefficient sequence cannot drift from
/// the specialized backends because they share the driver.
#[allow(clippy::too_many_arguments)]
pub fn op_filter_window_into(
    op: &SpectralOp,
    y0: &Mat,
    params: &FilterParams,
    degrees: &[usize],
    out: &mut Mat,
    tmp1: &mut Mat,
    tmp2: &mut Mat,
    threads: usize,
) -> usize {
    window_driver_f64(
        op.n(),
        y0,
        params,
        degrees,
        out,
        tmp1,
        tmp2,
        &mut |ca, x, cb, cc, z, y, j0, j1| op.apply_fused_cols_into(ca, x, cb, cc, z, y, j0, j1, threads),
    )
}

/// Flop cost of one filter application (used by benches and to report
/// the paper's "Filter Flops" column without re-instrumenting).
pub fn filter_flop_cost(a: &CsrMatrix, k: usize, degree: usize) -> u64 {
    let per_step = 2 * a.nnz() as u64 * k as u64 + 4 * a.rows() as u64 * k as u64;
    per_step * degree as u64
}

/// Schedule-aware sibling of [`filter_flop_cost`]: the cost of one
/// shrinking-window application with per-column `degrees`. Matches the
/// instrumented flop counters of [`chebyshev_filter_window_into`]
/// exactly (each recurrence step costs `(2·nnz + 4·n)` flops per
/// *active* column, and `Σ_s w_s = Σ_j m_j`). A uniform schedule
/// reduces to `filter_flop_cost(a, k, m)`.
pub fn filter_flop_cost_schedule(a: &CsrMatrix, degrees: &[usize]) -> u64 {
    let per_col_step = 2 * a.nnz() as u64 + 4 * a.rows() as u64;
    per_col_step * degrees.iter().map(|&d| d as u64).sum::<u64>()
}

/// Run a filter application while separately accounting its flops.
/// Returns `(filtered, filter_flops)`.
pub fn filtered_with_flops(
    backend: &mut dyn FilterBackend,
    op: &SpectralOp,
    y: &Mat,
    params: &FilterParams,
) -> (Mat, u64) {
    let before = flops::read();
    let out = backend.filter(op, y, params);
    (out, flops::read().wrapping_sub(before))
}

/// Zero-alloc sibling of [`filtered_with_flops`]: the result lands in
/// `out`, the returned value is the filter's flop count.
#[allow(clippy::too_many_arguments)]
pub fn filtered_into_with_flops(
    backend: &mut dyn FilterBackend,
    op: &SpectralOp,
    y: &Mat,
    params: &FilterParams,
    out: &mut Mat,
    tmp1: &mut Mat,
    tmp2: &mut Mat,
    threads: usize,
) -> u64 {
    let before = flops::read();
    backend.filter_into(op, y, params, out, tmp1, tmp2, threads);
    flops::read().wrapping_sub(before)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::symeig::sym_eig;
    use crate::operators::{self, GenOptions, OperatorKind};
    use crate::rng::Xoshiro256pp;

    fn test_problem() -> CsrMatrix {
        operators::generate(
            OperatorKind::Poisson,
            GenOptions {
                grid: 8,
                ..Default::default()
            },
            1,
            1,
        )
        .remove(0)
        .matrix
    }

    #[test]
    fn matrix_filter_matches_scalar_filter_on_eigenbasis() {
        // p_m(A) v_j = p_m(λ_j) v_j: validate the block recurrence
        // against the scalar evaluation, per eigenvector.
        let a = test_problem();
        let eig = sym_eig(&a.to_dense());
        let params = FilterParams {
            degree: 8,
            lower: eig.values[10],
            upper: *eig.values.last().unwrap() + 1.0,
            target: eig.values[0],
        };
        let v = eig.vectors.cols_range(0, 6);
        let filtered = chebyshev_filter(&a, &v, &params);
        for j in 0..6 {
            let scale = params.eval_scalar(eig.values[j]);
            for i in 0..a.rows() {
                let want = scale * v[(i, j)];
                assert!(
                    (filtered[(i, j)] - want).abs() < 1e-6 * scale.abs().max(1.0),
                    "entry ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn scalar_filter_bounded_on_damped_interval() {
        let params = FilterParams {
            degree: 20,
            lower: 2.0,
            upper: 10.0,
            target: 0.5,
        };
        // The σ-scaled filter is ρ_m(t) = C_m((t−c)/e) / C_m((λ−c)/e):
        // exactly 1 at the target and exponentially small on [α, β].
        let at_target = params.eval_scalar(0.5);
        assert!((at_target - 1.0).abs() < 1e-9, "ρ(λ) = {at_target}");
        for t in [2.0, 3.0, 5.0, 7.5, 10.0] {
            assert!(
                params.eval_scalar(t).abs() < 1e-6,
                "t={t}: {}",
                params.eval_scalar(t)
            );
        }
    }

    #[test]
    fn amplification_grows_toward_target() {
        // Relative amplification increases monotonically as t moves from
        // the damped edge α toward (and past) the target λ.
        let params = FilterParams {
            degree: 20,
            lower: 2.0,
            upper: 10.0,
            target: 0.5,
        };
        let g_edge = params.eval_scalar(2.0).abs();
        let g1 = params.eval_scalar(1.5).abs();
        let g2 = params.eval_scalar(1.0).abs();
        let g3 = params.eval_scalar(0.6).abs();
        assert!(g_edge < g1 && g1 < g2 && g2 < g3, "{g_edge} {g1} {g2} {g3}");
        assert!(g3 <= 1.0 + 1e-9);
    }

    #[test]
    fn filter_improves_rayleigh_quotient_toward_smallest() {
        // One filter pass on a random block must rotate it toward the
        // small end of the spectrum.
        let a = test_problem();
        let eig = sym_eig(&a.to_dense());
        let l = 6;
        let params = FilterParams {
            degree: 12,
            lower: eig.values[l],
            upper: *eig.values.last().unwrap() * 1.01,
            target: eig.values[0] * 0.95,
        };
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let y = Mat::randn(a.rows(), l, &mut rng);
        let q0 = crate::linalg::qr::householder_qr(&y);
        let before = q0.t_matmul(&a.spmm_alloc(&q0));
        let filtered = chebyshev_filter(&a, &y, &params);
        let q1 = crate::linalg::qr::householder_qr(&filtered);
        let after = q1.t_matmul(&a.spmm_alloc(&q1));
        let tr = |m: &Mat| (0..l).map(|i| m[(i, i)]).sum::<f64>();
        assert!(
            tr(&after) < tr(&before),
            "trace before {} after {}",
            tr(&before),
            tr(&after)
        );
    }

    #[test]
    fn degree_one_is_scaled_shift() {
        let a = test_problem();
        let params = FilterParams {
            degree: 1,
            lower: 5.0,
            upper: 20.0,
            target: 1.0,
        }
        .sanitized();
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let y = Mat::randn(a.rows(), 3, &mut rng);
        let out = chebyshev_filter(&a, &y, &params);
        // Y1 = (σ1/e)(A − cI) Y0 exactly.
        let c = params.center();
        let e = params.half_width();
        let s1 = e / (params.target - c);
        let mut want = a.spmm_alloc(&y);
        want.axpy(-c, &y);
        want.scale(s1 / e);
        assert!(out.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn sanitize_fixes_degenerate_intervals() {
        let p = FilterParams {
            degree: 5,
            lower: 3.0,
            upper: 3.0,
            target: 4.0,
        }
        .sanitized();
        assert!(p.upper > p.lower);
        assert!(p.target < p.lower);
    }

    #[test]
    fn filter_into_matches_alloc_filter_for_any_thread_count() {
        let a = test_problem();
        let params = FilterParams {
            degree: 9,
            lower: 5.0,
            upper: 60.0,
            target: 1.0,
        };
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let y = Mat::randn(a.rows(), 5, &mut rng);
        let want = chebyshev_filter(&a, &y, &params);
        for threads in [1usize, 2, 4] {
            let mut out = Mat::zeros(0, 0);
            let mut t1 = Mat::zeros(0, 0);
            let mut t2 = Mat::zeros(0, 0);
            chebyshev_filter_into(&a, &y, &params, &mut out, &mut t1, &mut t2, threads);
            assert_eq!(out, want, "threads = {threads}");
        }
        // The backend default path agrees too.
        let op = SpectralOp::standard(&a);
        let mut backend = NativeFilter::new();
        let mut out = Mat::zeros(0, 0);
        let (mut t1, mut t2) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
        backend.filter_into(&op, &y, &params, &mut out, &mut t1, &mut t2, 2);
        assert_eq!(out, want);
        // And the operator-generic recurrence reproduces the CSR
        // arithmetic for the plain op (full and window paths).
        let mut gout = Mat::zeros(0, 0);
        op_chebyshev_filter_into(&op, &y, &params, &mut gout, &mut t1, &mut t2, 2);
        assert_eq!(gout, want);
        let applied =
            op_filter_window_into(&op, &y, &params, &[9; 5], &mut gout, &mut t1, &mut t2, 2);
        assert_eq!(applied, 45);
        assert_eq!(gout, want);
    }

    #[test]
    fn window_filter_with_uniform_degrees_is_bit_for_bit_plain_filter() {
        let a = test_problem();
        let params = FilterParams {
            degree: 11,
            lower: 5.0,
            upper: 60.0,
            target: 1.0,
        };
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        let y = Mat::randn(a.rows(), 6, &mut rng);
        let want = chebyshev_filter(&a, &y, &params);
        for threads in [1usize, 2, 4] {
            let mut out = Mat::zeros(0, 0);
            let (mut t1, mut t2) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
            let applied = chebyshev_filter_window_into(
                &a, &y, &params, &[11; 6], &mut out, &mut t1, &mut t2, threads,
            );
            assert_eq!(applied, 66);
            assert_eq!(out, want, "threads = {threads}");
        }
    }

    #[test]
    fn window_filter_gives_each_column_its_standalone_degree() {
        // The σ sequence depends only on the step index, so a column
        // retiring at degree m must equal the standalone degree-m
        // filter of that column — for every retirement pattern the
        // 3-buffer rotation can produce.
        let a = test_problem();
        let params = FilterParams {
            degree: 14,
            lower: 5.0,
            upper: 60.0,
            target: 1.0,
        };
        let mut rng = Xoshiro256pp::seed_from_u64(22);
        let y = Mat::randn(a.rows(), 7, &mut rng);
        for degrees in [
            vec![14usize, 12, 9, 9, 5, 2, 1],
            vec![14, 14, 14, 13, 13, 12, 11],
            vec![6, 5, 4, 3, 2, 1, 1],
            vec![14, 1, 1, 1, 1, 1, 1],
            vec![3, 3, 3, 3, 3, 3, 3],
        ] {
            let mut out = Mat::zeros(0, 0);
            let (mut t1, mut t2) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
            let applied = chebyshev_filter_window_into(
                &a, &y, &params, &degrees, &mut out, &mut t1, &mut t2, 2,
            );
            assert_eq!(applied, degrees.iter().sum::<usize>());
            for (j, &m) in degrees.iter().enumerate() {
                let pj = FilterParams { degree: m, ..params };
                let want = chebyshev_filter(&a, &y.cols_range(j, j + 1), &pj);
                for i in 0..a.rows() {
                    assert_eq!(out[(i, j)], want[(i, 0)], "col {j} deg {m} ({degrees:?})");
                }
            }
        }
    }

    #[test]
    fn backend_window_default_falls_back_to_max_degree() {
        // A backend without a native window path (the XLA route) must
        // stay correct: the default filters everything at the max
        // degree and reports the full matvec count.
        struct Plain;
        impl FilterBackend for Plain {
            fn filter(&mut self, op: &SpectralOp, y: &Mat, params: &FilterParams) -> Mat {
                chebyshev_filter(op.plain().unwrap(), y, params)
            }
            fn name(&self) -> &'static str {
                "plain"
            }
        }
        let a = test_problem();
        let op = SpectralOp::standard(&a);
        let params = FilterParams {
            degree: 9,
            lower: 5.0,
            upper: 60.0,
            target: 1.0,
        };
        let mut rng = Xoshiro256pp::seed_from_u64(23);
        let y = Mat::randn(a.rows(), 4, &mut rng);
        let mut out = Mat::zeros(0, 0);
        let (mut t1, mut t2) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
        let mut backend = Plain;
        let applied = backend
            .filter_window_into(&op, &y, &params, &[7, 5, 3, 2], &mut out, &mut t1, &mut t2, 1);
        assert_eq!(applied, 4 * 7);
        let p7 = FilterParams { degree: 7, ..params };
        assert_eq!(out, chebyshev_filter(&a, &y, &p7));
    }

    #[test]
    fn required_degree_tracks_residual_and_interval() {
        let params = FilterParams {
            degree: 20,
            lower: 2.0,
            upper: 10.0,
            target: 0.5,
        };
        let cap = 20;
        // Unknown residual / guard columns inside the damped interval
        // get the cap.
        assert_eq!(required_degree(f64::INFINITY, 1e-8, 1.0, &params, cap), cap);
        assert_eq!(required_degree(1e-2, 1e-8, 3.0, &params, cap), cap);
        // Converged columns get the floor.
        assert_eq!(
            required_degree(1e-12, 1e-8, 0.6, &params, cap),
            MIN_ADAPTIVE_DEGREE
        );
        // Monotone: smaller residual → smaller degree; θ closer to the
        // damped interval → larger degree.
        let d_hi = required_degree(1e-1, 1e-8, 0.6, &params, cap);
        let d_mid = required_degree(1e-4, 1e-8, 0.6, &params, cap);
        let d_lo = required_degree(1e-7, 1e-8, 0.6, &params, cap);
        assert!(d_hi >= d_mid && d_mid >= d_lo, "{d_hi} {d_mid} {d_lo}");
        assert!(d_lo >= MIN_ADAPTIVE_DEGREE);
        let near_edge = required_degree(1e-4, 1e-8, 1.9, &params, cap);
        assert!(near_edge >= d_mid, "edge {near_edge} vs mid {d_mid}");
        // Never exceeds the cap.
        assert!(required_degree(1e3, 1e-12, 1.99, &params, cap) <= cap);
    }

    #[test]
    fn schedule_flop_cost_matches_instrumented_window() {
        let a = test_problem();
        let params = FilterParams {
            degree: 10,
            lower: 5.0,
            upper: 50.0,
            target: 1.0,
        };
        let mut rng = Xoshiro256pp::seed_from_u64(24);
        let y = Mat::randn(a.rows(), 5, &mut rng);
        let degrees = [10usize, 8, 4, 2, 1];
        let before = flops::read();
        let mut out = Mat::zeros(0, 0);
        let (mut t1, mut t2) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
        chebyshev_filter_window_into(&a, &y, &params, &degrees, &mut out, &mut t1, &mut t2, 1);
        let counted = flops::read().wrapping_sub(before);
        assert_eq!(counted, filter_flop_cost_schedule(&a, &degrees));
        // Uniform schedules agree with the historical cost formula.
        assert_eq!(
            filter_flop_cost_schedule(&a, &[7; 4]),
            filter_flop_cost(&a, 4, 7)
        );
    }

    #[test]
    fn filter_schedule_names_roundtrip() {
        for s in [FilterSchedule::Fixed, FilterSchedule::Adaptive] {
            assert_eq!(FilterSchedule::parse(s.name()), Some(s));
        }
        assert_eq!(FilterSchedule::parse("nope"), None);
        assert_eq!(FilterSchedule::default(), FilterSchedule::Fixed);
    }

    #[test]
    fn precision_and_backend_kind_names_roundtrip() {
        for p in [Precision::F64, Precision::Mixed] {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::parse("f32"), None);
        assert_eq!(Precision::default(), Precision::F64);
        for b in [FilterBackendKind::Csr, FilterBackendKind::Sell] {
            assert_eq!(FilterBackendKind::parse(b.name()), Some(b));
        }
        assert_eq!(FilterBackendKind::parse("ellpack"), None);
        assert_eq!(FilterBackendKind::default(), FilterBackendKind::Csr);
    }

    #[test]
    fn promotion_floor_tracks_tolerance_and_conditioning() {
        // A loose tolerance dominates the floor (column never promotes
        // on accuracy grounds); a tight tolerance exposes the f32 term.
        assert_eq!(f32_promotion_floor(1e-2, 100, 10.0, 1.0), 1e-2);
        let tight = f32_promotion_floor(1e-12, 100, 10.0, 1.0);
        assert!(tight > 1e-12 && tight < 1e-3, "{tight}");
        // Smaller Ritz values (relative residual divides by θ) and
        // larger spectra raise the floor.
        assert!(
            f32_promotion_floor(1e-12, 100, 1e4, 1.0) > f32_promotion_floor(1e-12, 100, 10.0, 1.0)
        );
        assert!(
            f32_promotion_floor(1e-12, 100, 1e4, 1.0)
                >= f32_promotion_floor(1e-12, 100, 1e4, 100.0)
        );
        // Guard against degenerate inputs: θ = 0 must not blow up.
        assert!(f32_promotion_floor(1e-12, 100, 10.0, 0.0).is_finite());
    }

    #[test]
    fn sell_backend_matches_csr_backend_in_f64() {
        // Same driver, same coefficients — SELL differs from CSR only
        // by per-row accumulation grouping, so results agree to
        // rounding, and the full/window entry points are mutually
        // consistent.
        let a = test_problem();
        let params = FilterParams {
            degree: 9,
            lower: 5.0,
            upper: 60.0,
            target: 1.0,
        };
        let mut rng = Xoshiro256pp::seed_from_u64(31);
        let y = Mat::randn(a.rows(), 5, &mut rng);
        let want = chebyshev_filter(&a, &y, &params);
        let op = SpectralOp::standard(&a);
        let mut sell = SellFilter::new();
        sell.begin_solve(&op);
        let got = sell.filter(&op, &y, &params);
        let scale = want.fro_norm().max(1.0);
        assert!(got.max_abs_diff(&want) < 1e-10 * scale);
        // Window path with uniform degrees equals the full filter
        // bit-for-bit (same kernels, same call sequence).
        let mut out = Mat::zeros(0, 0);
        let (mut t1, mut t2) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
        let applied =
            sell.filter_window_into(&op, &y, &params, &[9; 5], &mut out, &mut t1, &mut t2, 2);
        assert_eq!(applied, 45);
        assert_eq!(out, got);
    }

    #[test]
    fn f32_window_filter_tracks_f64_within_single_precision() {
        let a = test_problem();
        let params = FilterParams {
            degree: 8,
            lower: 5.0,
            upper: 60.0,
            target: 1.0,
        };
        let mut rng = Xoshiro256pp::seed_from_u64(32);
        let y = Mat::randn(a.rows(), 4, &mut rng);
        let degrees = [8usize, 8, 5, 2];
        let mut want = Mat::zeros(0, 0);
        let (mut t1, mut t2) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
        chebyshev_filter_window_into(&a, &y, &params, &degrees, &mut want, &mut t1, &mut t2, 1);
        let y32 = MatF32::from_f64(&y);
        let op = SpectralOp::standard(&a);
        for (label, mut backend) in [
            ("csr", Box::new(NativeFilter::new()) as Box<dyn FilterBackend>),
            ("sell", Box::new(SellFilter::new()) as Box<dyn FilterBackend>),
        ] {
            backend.begin_solve(&op);
            let mut o32 = MatF32::zeros(0, 0);
            let (mut a32, mut b32) = (MatF32::zeros(0, 0), MatF32::zeros(0, 0));
            let applied = backend.filter_window_f32_into(
                &op, &y32, &params, &degrees, &mut o32, &mut a32, &mut b32, 2,
            );
            assert_eq!(applied, 23, "{label}");
            let got = o32.to_f64();
            let scale = want.fro_norm().max(1.0);
            assert!(
                got.max_abs_diff(&want) < 1e-3 * scale,
                "{label}: {}",
                got.max_abs_diff(&want) / scale
            );
        }
    }

    #[test]
    fn default_f32_window_upcasts_and_stays_correct() {
        // A backend that only implements `filter` (the XLA shape) must
        // get a *correct* f32 window via the trait default, equal to
        // its own f64 fallback rounded to f32.
        struct Plain;
        impl FilterBackend for Plain {
            fn filter(&mut self, op: &SpectralOp, y: &Mat, params: &FilterParams) -> Mat {
                chebyshev_filter(op.plain().unwrap(), y, params)
            }
            fn name(&self) -> &'static str {
                "plain"
            }
        }
        let a = test_problem();
        let op = SpectralOp::standard(&a);
        let params = FilterParams {
            degree: 7,
            lower: 5.0,
            upper: 60.0,
            target: 1.0,
        };
        let mut rng = Xoshiro256pp::seed_from_u64(33);
        let y = Mat::randn(a.rows(), 3, &mut rng);
        let y32 = MatF32::from_f64(&y);
        let mut plain = Plain;
        let mut o32 = MatF32::zeros(0, 0);
        let (mut a32, mut b32) = (MatF32::zeros(0, 0), MatF32::zeros(0, 0));
        let applied = plain
            .filter_window_f32_into(&op, &y32, &params, &[7, 4, 2], &mut o32, &mut a32, &mut b32, 1);
        // Default ignores the schedule: max degree × columns.
        assert_eq!(applied, 21);
        let p7 = FilterParams { degree: 7, ..params };
        let want32 = MatF32::from_f64(&chebyshev_filter(&a, &y32.to_f64(), &p7));
        assert_eq!(o32.to_f64(), want32.to_f64());
    }

    #[test]
    fn begin_solve_invalidates_cached_operator() {
        // Chained solves reuse one backend across problems with the
        // same sparsity but different values; a stale f32 cache would
        // silently filter with the old operator.
        let a = test_problem();
        let b = a.scaled(2.0);
        let params = FilterParams {
            degree: 6,
            lower: 5.0,
            upper: 120.0,
            target: 1.0,
        };
        let mut rng = Xoshiro256pp::seed_from_u64(34);
        let y = Mat::randn(a.rows(), 3, &mut rng);
        let y32 = MatF32::from_f64(&y);
        let degrees = [6usize, 6, 6];
        let run = |backend: &mut NativeFilter, m: &CsrMatrix| {
            let op = SpectralOp::standard(m);
            backend.begin_solve(&op);
            let mut o32 = MatF32::zeros(0, 0);
            let (mut t1, mut t2) = (MatF32::zeros(0, 0), MatF32::zeros(0, 0));
            backend.filter_window_f32_into(&op, &y32, &params, &degrees, &mut o32, &mut t1, &mut t2, 1);
            o32.to_f64()
        };
        let mut fresh = NativeFilter::new();
        let want_b = run(&mut fresh, &b);
        let mut reused = NativeFilter::new();
        let _ = run(&mut reused, &a);
        let got_b = run(&mut reused, &b);
        assert_eq!(got_b, want_b);
    }

    #[test]
    fn flop_cost_matches_instrumented_count() {
        let a = test_problem();
        let params = FilterParams {
            degree: 7,
            lower: 5.0,
            upper: 50.0,
            target: 1.0,
        };
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let y = Mat::randn(a.rows(), 4, &mut rng);
        let op = SpectralOp::standard(&a);
        let mut backend = NativeFilter::new();
        let (_, counted) = filtered_with_flops(&mut backend, &op, &y, &params);
        let predicted = filter_flop_cost(&a, 4, 7);
        // The clone of Y0 and swaps cost nothing; counts must match.
        assert_eq!(counted, predicted);
    }
}
