"""L2 correctness: the full filter graph vs oracle + spectral semantics."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402


def sym_psd(n, seed):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    lam = np.sort(rng.uniform(0.5, 50.0, n))
    return (q * lam) @ q.T, lam, q


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(min_value=1, max_value=6),
    m=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_filter_matches_jnp_reference(k, m, seed):
    n = 16
    a, lam, _ = sym_psd(n, seed)
    rng = np.random.default_rng(seed + 1)
    y0 = rng.standard_normal((n, k))
    target, c, e = lam[0] - 0.1, (lam[k] + lam[-1]) / 2, (lam[-1] - lam[k]) / 2
    got = model.chebyshev_filter(a, y0, target, c, e, degree=m)
    want = ref.ref_chebyshev_filter(a, y0, target, c, e, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-9, atol=1e-9)


def test_filter_acts_as_scalar_filter_on_eigenvectors():
    # p_m(A) q_j = p_m(lam_j) q_j — the defining property.
    n, m = 24, 10
    a, lam, q = sym_psd(n, 7)
    lsplit = 6
    target = lam[0] - 0.05
    c = (lam[lsplit] + lam[-1]) / 2
    e = (lam[-1] - lam[lsplit]) / 2
    out = np.asarray(model.chebyshev_filter(a, q, target, c, e, degree=m))
    for j in range(n):
        rho = float(ref.ref_scalar_filter(lam[j], target, c, e, m))
        np.testing.assert_allclose(out[:, j], rho * q[:, j], rtol=1e-7, atol=1e-8)


def test_filter_damps_unwanted_amplifies_wanted():
    n, m = 24, 20
    a, lam, q = sym_psd(n, 11)
    lsplit = 4
    target = lam[0] - 0.05
    c = (lam[lsplit] + lam[-1]) / 2
    e = (lam[-1] - lam[lsplit]) / 2
    # Mix of the smallest and the largest eigenvector.
    y = (q[:, [0]] + q[:, [-1]]) / np.sqrt(2)
    out = np.asarray(model.chebyshev_filter(a, y, target, c, e, degree=m))
    coef_small = abs(q[:, 0] @ out[:, 0])
    coef_large = abs(q[:, -1] @ out[:, 0])
    assert coef_small > 1e3 * coef_large, (coef_small, coef_large)


def test_residual_norms_zero_for_exact_pairs():
    n = 16
    a, lam, q = sym_psd(n, 3)
    out = np.asarray(model.residual_norms(a, q[:, :5], lam[:5]))
    assert out.shape == (5,)
    np.testing.assert_allclose(out, 0.0, atol=1e-12)


def test_residual_norms_positive_for_wrong_pairs():
    n = 16
    a, lam, q = sym_psd(n, 4)
    wrong = lam[:5] * 1.5
    out = np.asarray(model.residual_norms(a, q[:, :5], wrong))
    assert (out > 0.05).all()


@pytest.mark.parametrize("degree", [1, 2, 20])
def test_degree_is_respected(degree):
    # degree-m output is a degree-m polynomial in A: check via the
    # scalar filter at a random eigenvalue.
    n = 12
    a, lam, q = sym_psd(n, 5)
    target, c, e = lam[0] - 0.1, (lam[4] + lam[-1]) / 2, (lam[-1] - lam[4]) / 2
    y = q[:, [2]]
    out = np.asarray(model.chebyshev_filter(a, y, target, c, e, degree=degree))
    rho = float(ref.ref_scalar_filter(lam[2], target, c, e, degree))
    np.testing.assert_allclose(out[:, 0], rho * y[:, 0], rtol=1e-8, atol=1e-10)
