//! End-to-end tests of the streaming, resumable dataset store: the
//! chunked (schema-3) manifest, crash-resume from arbitrary torn
//! states, and the streaming/shared readers.
//!
//! The centerpiece is a kill-at-any-byte property test: generate a
//! chunked dataset per operator family, truncate its manifest at a
//! spread of byte offsets (frame boundaries, mid-payload, mid-header),
//! optionally tear `eigs.bin` back to the checkpoint too, resume, and
//! demand the result is indistinguishable from the uninterrupted run —
//! byte-identical `eigs.bin` records and manifest record fields (minus
//! arrival-dependent `offset` and wall-clock `secs`).

use scsf::coordinator::config::{FamilySpec, GenConfig};
use scsf::coordinator::dataset::{scan_resumable, DatasetReader, RecordMeta};
use scsf::coordinator::pipeline::{generate_dataset, resume_dataset};
use scsf::sort::SortMethod;
use std::path::{Path, PathBuf};

/// The five built-in operator families.
const FAMILIES: [&str; 5] = [
    "poisson",
    "elliptic",
    "helmholtz",
    "vibration",
    "helmholtz_fem",
];

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "scsf_stream_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Small-but-real chunked config: two shards (so resume must reconcile
/// interleaved runs), warm chains on (so resume must re-seed them),
/// checkpoint every 2 records.
fn chunked_cfg(family: &str) -> GenConfig {
    GenConfig {
        families: vec![FamilySpec::new(family, 6)],
        grid: 8,
        n_eigs: 3,
        tol: Some(1e-7),
        seed: 23,
        shards: 2,
        channel_capacity: 2,
        sort: SortMethod::TruncatedFft { p0: 6 },
        chunk_records: Some(2),
        ..Default::default()
    }
}

fn copy_dataset(src: &Path, dst: &Path) {
    let _ = std::fs::remove_dir_all(dst);
    std::fs::create_dir_all(dst).unwrap();
    for f in ["eigs.bin", "manifest.json"] {
        std::fs::copy(src.join(f), dst.join(f)).unwrap();
    }
}

fn truncate_file(path: &Path, len: u64) {
    let f = std::fs::OpenOptions::new().write(true).open(path).unwrap();
    f.set_len(len).unwrap();
}

/// A record's exact byte span in `eigs.bin`.
fn record_bytes<'a>(bin: &'a [u8], meta: &RecordMeta) -> &'a [u8] {
    let len = 3 * 8 + meta.l * 8 + meta.n * meta.l * 8;
    &bin[meta.offset as usize..meta.offset as usize + len]
}

/// Strip the fields a resumed run may legitimately change: `offset`
/// depends on nondeterministic arrival interleave, `secs` on the clock.
fn normalized(meta: &RecordMeta) -> RecordMeta {
    let mut m = meta.clone();
    m.offset = 0;
    m.secs = 0.0;
    m
}

/// Assert the dataset in `got` stores exactly the records of `want`:
/// identical per-id record bytes in `eigs.bin`, identical manifest
/// record fields modulo `offset`/`secs`.
fn assert_same_dataset(want: &Path, got: &Path, ctx: &str) {
    let want_reader = DatasetReader::open(want).unwrap();
    let got_reader = DatasetReader::open(got).unwrap();
    assert_eq!(
        want_reader.index().len(),
        got_reader.index().len(),
        "{ctx}: record count"
    );
    let want_bin = std::fs::read(want.join("eigs.bin")).unwrap();
    let got_bin = std::fs::read(got.join("eigs.bin")).unwrap();
    // Both indexes are sorted by id.
    for (a, b) in want_reader.index().iter().zip(got_reader.index()) {
        assert_eq!(normalized(a), normalized(b), "{ctx}: record {} meta", a.id);
        assert_eq!(
            record_bytes(&want_bin, a),
            record_bytes(&got_bin, b),
            "{ctx}: record {} bytes differ",
            a.id
        );
    }
}

/// Byte offsets at which to kill the manifest: the file start, inside
/// the header, every frame boundary, and mid-payload points between
/// them. A frame is a payload line plus a trailer line, so frame
/// boundaries sit after every second newline.
fn kill_offsets(manifest: &[u8]) -> Vec<u64> {
    let newlines: Vec<usize> = manifest
        .iter()
        .enumerate()
        .filter_map(|(i, b)| (*b == b'\n').then_some(i))
        .collect();
    let boundaries: Vec<u64> = newlines
        .iter()
        .skip(1)
        .step_by(2)
        .map(|&i| (i + 1) as u64)
        .collect();
    let mut offsets = vec![0, 1, boundaries[0] - 1];
    offsets.extend(boundaries.iter().copied());
    // Mid-payload: halfway into each frame after the header.
    for w in boundaries.windows(2) {
        offsets.push((w[0] + w[1]) / 2);
    }
    offsets.push(manifest.len() as u64 - 1);
    offsets.sort_unstable();
    offsets.dedup();
    // Never include the untouched length: a complete dataset is not
    // resumable (by design), which a separate test asserts.
    offsets.retain(|&o| o < manifest.len() as u64);
    offsets
}

#[test]
fn kill_at_any_byte_then_resume_reproduces_the_dataset() {
    for family in FAMILIES {
        let base = tmpdir(&format!("kill_base_{family}"));
        let cfg = chunked_cfg(family);
        generate_dataset(&cfg, &base).unwrap();
        let manifest_bytes = std::fs::read(base.join("manifest.json")).unwrap();
        // Header frame = first payload line + first trailer line, so
        // it ends right after the second newline.
        let header_len = manifest_bytes
            .iter()
            .enumerate()
            .filter(|(_, b)| **b == b'\n')
            .nth(1)
            .map(|(i, _)| i as u64 + 1)
            .unwrap();
        let work = tmpdir(&format!("kill_work_{family}"));
        for (i, off) in kill_offsets(&manifest_bytes).into_iter().enumerate() {
            let ctx = format!("{family} killed at byte {off}");
            copy_dataset(&base, &work);
            truncate_file(&work.join("manifest.json"), off);
            if off < header_len {
                // Nothing durable survives without a header: resume
                // must fail cleanly, not corrupt or invent data.
                let err = resume_dataset(&work).unwrap_err().to_string();
                assert!(
                    err.contains("torn before its header frame"),
                    "{ctx}: {err}"
                );
                continue;
            }
            // Alternate between a crash that also tore eigs.bin back
            // to the checkpoint and one that left extra (undurable)
            // eigenpair bytes for the writer to truncate.
            let scan = scan_resumable(&work).unwrap();
            assert!(!scan.complete, "{ctx}: footer must be gone");
            if i % 2 == 0 {
                truncate_file(&work.join("eigs.bin"), scan.point.eigs_bytes);
            }
            let report = resume_dataset(&work).unwrap();
            assert_eq!(report.n_problems, 6, "{ctx}");
            assert_eq!(report.resumed_records, scan.records.len(), "{ctx}");
            assert_same_dataset(&base, &work, &ctx);
            let reader = DatasetReader::open(&work).unwrap();
            assert!(reader.layout().unwrap().complete, "{ctx}");
            // A resumed dataset is complete: resuming again is an error.
            let err = resume_dataset(&work).unwrap_err().to_string();
            assert!(err.contains("nothing to resume"), "{ctx}: {err}");
        }
        let _ = std::fs::remove_dir_all(&base);
        let _ = std::fs::remove_dir_all(&work);
    }
}

#[test]
fn streaming_reader_matches_random_access() {
    let dir = tmpdir("stream_match");
    let cfg = chunked_cfg("helmholtz");
    generate_dataset(&cfg, &dir).unwrap();
    let mut reader = DatasetReader::open(&dir).unwrap();
    let metas: Vec<RecordMeta> = reader.index().to_vec();
    // Stream in storage order, skipping every third record.
    let mut stream = reader.stream().unwrap();
    let mut seen = 0usize;
    let mut pos = 0usize;
    while let Some(meta) = stream.peek_meta().cloned() {
        if pos % 3 == 2 {
            stream.skip_record();
            pos += 1;
            continue;
        }
        let view = stream.next_record().unwrap().unwrap();
        assert_eq!(view.id, meta.id);
        let rec = reader.read(meta.id).unwrap();
        assert_eq!(view.values, &rec.values[..]);
        assert_eq!(view.vectors, rec.vectors.data());
        seen += 1;
        pos += 1;
    }
    assert_eq!(pos, metas.len());
    assert_eq!(seen, metas.len() - metas.len() / 3);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shared_readers_serve_concurrent_threads() {
    let dir = tmpdir("shared_conc");
    let cfg = chunked_cfg("poisson");
    generate_dataset(&cfg, &dir).unwrap();
    let mut reader = DatasetReader::open(&dir).unwrap();
    let want: Vec<_> = (0..6).map(|id| reader.read(id).unwrap()).collect();
    let shared = reader.into_shared();
    std::thread::scope(|scope| {
        for t in 0..3usize {
            let shared = shared.clone();
            let want = &want;
            scope.spawn(move || {
                let mut cursor = shared.cursor().unwrap();
                let ids: Vec<usize> = if t % 2 == 0 {
                    (0..6).collect()
                } else {
                    (0..6).rev().collect()
                };
                for id in ids {
                    let rec = cursor.read(id).unwrap();
                    assert_eq!(rec.values, want[id].values, "thread {t} id {id}");
                    assert_eq!(rec.vectors, want[id].vectors, "thread {t} id {id}");
                }
            });
        }
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn legacy_default_is_untouched_and_not_resumable() {
    let dir = tmpdir("legacy_shape");
    let mut cfg = chunked_cfg("helmholtz");
    cfg.chunk_records = None; // the default: legacy one-shot manifest
    generate_dataset(&cfg, &dir).unwrap();
    assert!(
        !dir.join("manifest.json.tmp").exists(),
        "finalize must clean up its temp file"
    );
    let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    let v = scsf::util::json::parse(&text).unwrap();
    assert_eq!(
        v.get("schema_version")
            .and_then(scsf::util::json::Value::as_usize),
        Some(2)
    );
    let mut reader = DatasetReader::open(&dir).unwrap();
    assert_eq!(reader.schema_version(), 2);
    assert!(reader.layout().is_none(), "legacy manifests have no layout");
    assert_eq!(reader.index().len(), 6);
    let _ = reader.read(0).unwrap();
    let err = resume_dataset(&dir).unwrap_err().to_string();
    assert!(err.contains("--chunk-records"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
