//! Dense linear algebra built from scratch: matrices, BLAS-like kernels,
//! Householder QR, and a symmetric eigensolver.
//!
//! All solver numerics are `f64`. Matrices are row-major. Subspace
//! blocks (the `n × k` iterate of every solver, `k ≪ n`) are also
//! `Mat`s. The one exception is [`dense::MatF32`], the iterate storage
//! of the mixed-precision Chebyshev sweeps — every Rayleigh–Ritz,
//! residual, and locking stage still runs in f64.
//!
//! ## Flop accounting
//!
//! The paper's Table 3 reports flop counts, and EXPERIMENTS.md uses flops
//! as the machine-independent comparison. Every kernel in [`dense`],
//! [`qr`], [`symeig`] and [`crate::sparse`] adds its cost to a
//! thread-local counter ([`flops::add`]); solvers snapshot it with
//! [`flops::take`]. Each eigensolve runs on a single thread, so
//! thread-local counting is exact (parallel section costs are added at
//! the dispatch site, not inside workers).

pub mod dense;
pub mod qr;
pub mod symeig;

pub use dense::{Mat, MatF32};

/// Thread-local floating-point-operation counter.
pub mod flops {
    use std::cell::Cell;

    thread_local! {
        static FLOPS: Cell<u64> = const { Cell::new(0) };
    }

    /// Add `n` flops to this thread's counter.
    #[inline]
    pub fn add(n: u64) {
        FLOPS.with(|f| f.set(f.get().wrapping_add(n)));
    }

    /// Read the counter without resetting it.
    pub fn read() -> u64 {
        FLOPS.with(|f| f.get())
    }

    /// Reset the counter to zero and return the previous value.
    pub fn take() -> u64 {
        FLOPS.with(|f| f.replace(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flop_counter_accumulates_and_takes() {
        flops::take();
        flops::add(10);
        flops::add(5);
        assert_eq!(flops::read(), 15);
        assert_eq!(flops::take(), 15);
        assert_eq!(flops::read(), 0);
    }
}
