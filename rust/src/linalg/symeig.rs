//! Dense symmetric eigensolver: Householder tridiagonalization (tred2)
//! followed by the implicit-shift QL iteration (tql2).
//!
//! This is the Rayleigh–Ritz engine (Algorithm 3, line 6) and the
//! projected-problem solver inside LOBPCG, Jacobi–Davidson, and the
//! restarted Lanczos variants. Projected problems are at most a few
//! hundred rows, where the classic EISPACK pair is entirely adequate.

use super::dense::Mat;
use super::flops;

/// Eigen-decomposition of a real symmetric matrix.
#[derive(Debug, Clone)]
pub struct SymEig {
    /// Eigenvalues in ascending order.
    pub values: Vec<f64>,
    /// Eigenvectors as columns, `vectors.col(j)` pairs with `values[j]`.
    pub vectors: Mat,
}

/// Compute all eigenpairs of symmetric `a` (the strict upper triangle is
/// ignored; the lower triangle is used). Panics on non-square input.
pub fn sym_eig(a: &Mat) -> SymEig {
    let mut out = SymEig {
        values: vec![],
        vectors: Mat::zeros(0, 0),
    };
    sym_eig_into(a, &mut out);
    out
}

/// Buffer-reusing variant of [`sym_eig`]: the decomposition is written
/// into `out`, whose value/vector storage is resized in place. The
/// Rayleigh–Ritz step of every outer solver iteration calls this with a
/// workspace-held `out`, so the k×k projected problem costs no heap
/// traffic after the first iteration. Arithmetic is identical to
/// [`sym_eig`] (same tred2/tql2 path), so results are bit-for-bit equal.
pub fn sym_eig_into(a: &Mat, out: &mut SymEig) {
    let n = a.rows();
    assert_eq!(n, a.cols(), "sym_eig expects a square matrix");
    out.values.clear();
    out.values.resize(n, 0.0);
    // Fully overwritten by the symmetrized copy below.
    out.vectors.set_shape(n, n);
    if n == 0 {
        return;
    }
    flops::add((9 * n * n * n) as u64); // classic tred2+tql2 cost estimate
    // z starts as the (symmetrized) input and ends as the eigenvector matrix.
    for i in 0..n {
        for j in 0..n {
            out.vectors[(i, j)] = if i >= j { a[(i, j)] } else { a[(j, i)] };
        }
    }
    // Off-diagonal scratch is thread-local so repeated Rayleigh–Ritz
    // calls stay allocation-free (each eigensolve runs on one thread).
    thread_local! {
        static E_SCRATCH: std::cell::RefCell<Vec<f64>> =
            const { std::cell::RefCell::new(Vec::new()) };
    }
    let mut e = E_SCRATCH.with(|c| std::mem::take(&mut *c.borrow_mut()));
    e.clear();
    e.resize(n, 0.0);
    tred2(&mut out.vectors, &mut out.values, &mut e);
    tql2(&mut out.vectors, &mut out.values, &mut e);
    // tql2 leaves (values, vectors) sorted ascending.
    E_SCRATCH.with(|c| *c.borrow_mut() = e);
}

/// All eigenpairs of the symmetric-definite generalized problem
/// `A x = λ M x` (`M` symmetric positive definite): dense Cholesky
/// `M = C Cᵀ`, reduction to the standard problem `C⁻¹ A C⁻ᵀ y = λ y`,
/// then back-substitution `x = C⁻ᵀ y`. Eigenvalues ascend; eigenvectors
/// are M-orthonormal (`xᵢᵀ M xⱼ = δᵢⱼ`), *not* Euclidean-orthonormal.
/// This is the small dense oracle the generalized property tests compare
/// the sparse solvers against. Panics on non-SPD `M`.
pub fn sym_eig_generalized(a: &Mat, m: &Mat) -> SymEig {
    let n = a.rows();
    assert_eq!(n, a.cols(), "sym_eig_generalized expects square A");
    assert_eq!(n, m.rows(), "A and M dimensions must agree");
    assert_eq!(n, m.cols(), "A and M dimensions must agree");
    if n == 0 {
        return SymEig {
            values: vec![],
            vectors: Mat::zeros(0, 0),
        };
    }
    // Lower-triangular Cholesky of the symmetrized M.
    let mut c = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mij = 0.5 * (m[(i, j)] + m[(j, i)]);
            let mut s = mij;
            for k in 0..j {
                s -= c[(i, k)] * c[(j, k)];
            }
            if i == j {
                assert!(s > 0.0, "mass matrix is not positive definite (pivot {i})");
                c[(i, i)] = s.sqrt();
            } else {
                c[(i, j)] = s / c[(j, j)];
            }
        }
    }
    flops::add((2 * n * n * n) as u64);
    // B = C⁻¹ A: forward-solve C b_col = a_col for every column.
    let mut b = Mat::zeros(n, n);
    for j in 0..n {
        for i in 0..n {
            let mut s = 0.5 * (a[(i, j)] + a[(j, i)]);
            for k in 0..i {
                s -= c[(i, k)] * b[(k, j)];
            }
            b[(i, j)] = s / c[(i, i)];
        }
    }
    // S = B C⁻ᵀ = C⁻¹ A C⁻ᵀ: forward-solve on the rows (Sᵀ = C⁻¹ Bᵀ).
    let mut s_red = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut s = b[(i, j)];
            for k in 0..j {
                s -= c[(j, k)] * s_red[(i, k)];
            }
            s_red[(i, j)] = s / c[(j, j)];
        }
    }
    flops::add((2 * n * n * n) as u64);
    let eig = sym_eig(&s_red);
    // Back-substitute every eigenvector: x = C⁻ᵀ y.
    let mut vectors = Mat::zeros(n, n);
    for j in 0..n {
        for i in (0..n).rev() {
            let mut s = eig.vectors[(i, j)];
            for k in (i + 1)..n {
                s -= c[(k, i)] * vectors[(k, j)];
            }
            vectors[(i, j)] = s / c[(i, i)];
        }
    }
    flops::add((n * n * n) as u64);
    SymEig {
        values: eig.values,
        vectors,
    }
}

/// Eigenvalues and eigenvectors of a symmetric tridiagonal matrix with
/// diagonal `d` and sub-diagonal `e` (`e[0]` unused). Used directly by the
/// Lanczos solvers to avoid forming the dense T.
pub fn tridiag_eig(diag: &[f64], sub: &[f64]) -> SymEig {
    let n = diag.len();
    assert_eq!(sub.len(), n.max(1) - 1);
    let mut z = Mat::eye(n);
    let mut d = diag.to_vec();
    // tql2's `e` convention: e[0] unused, e[i] couples rows i-1 and i,
    // then shifted down before iteration (EISPACK layout).
    let mut e = vec![0.0f64; n];
    for i in 1..n {
        e[i] = sub[i - 1];
    }
    flops::add((30 * n * n) as u64);
    tql2_raw(&mut z, &mut d, &mut e);
    SymEig {
        values: d,
        vectors: z,
    }
}

/// Householder reduction of a real symmetric matrix to tridiagonal form.
/// On exit `z` holds the accumulated orthogonal transform, `d` the
/// diagonal, `e[1..]` the sub-diagonal. (EISPACK tred2, zero-indexed.)
fn tred2(z: &mut Mat, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        let mut scale = 0.0;
        if l > 0 {
            for k in 0..=l {
                scale += z[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let mut f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                f = 0.0;
                for j in 0..=l {
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * z[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let upd = f * e[k] + g * z[(i, k)];
                        z[(j, k)] -= upd;
                    }
                }
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        let l = i;
        if d[i] != 0.0 {
            for j in 0..l {
                let mut g = 0.0;
                for k in 0..l {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..l {
                    let upd = g * z[(k, i)];
                    z[(k, j)] -= upd;
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
}

/// Implicit-shift QL for a symmetric tridiagonal matrix, accumulating the
/// transform into `z`. Expects EISPACK layout (`e[0]` unused). Sorts the
/// output ascending.
fn tql2(z: &mut Mat, d: &mut [f64], e: &mut [f64]) {
    tql2_raw(z, d, e);
}

fn tql2_raw(z: &mut Mat, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    if n == 0 {
        return;
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find a small sub-diagonal element to split at.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter <= 50, "tql2 failed to converge");
            // Form the implicit shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let mut s = 1.0;
            let mut c = 1.0;
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate the rotation into the eigenvector matrix.
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    // Sort eigenvalues (and vectors) ascending — selection sort, n is small.
    for i in 0..n {
        let mut kmin = i;
        for j in (i + 1)..n {
            if d[j] < d[kmin] {
                kmin = j;
            }
        }
        if kmin != i {
            d.swap(i, kmin);
            for r in 0..n {
                let tmp = z[(r, i)];
                z[(r, i)] = z[(r, kmin)];
                z[(r, kmin)] = tmp;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::ortho_defect;
    use crate::rng::Xoshiro256pp;

    fn random_symmetric(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let a = Mat::randn(n, n, &mut rng);
        let mut s = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                s[(i, j)] = 0.5 * (a[(i, j)] + a[(j, i)]);
            }
        }
        s
    }

    fn check_decomposition(a: &Mat, eig: &SymEig, tol: f64) {
        let n = a.rows();
        // A v = λ v for every pair.
        for j in 0..n {
            let v = eig.vectors.col(j);
            for i in 0..n {
                let mut av = 0.0;
                for k in 0..n {
                    av += a[(i, k)] * v[k];
                }
                let err = (av - eig.values[j] * v[i]).abs();
                assert!(err < tol, "residual {err} at pair {j}");
            }
        }
        // Ascending order.
        for j in 1..n {
            assert!(eig.values[j] >= eig.values[j - 1] - 1e-12);
        }
        // Orthonormal vectors.
        assert!(ortho_defect(&eig.vectors) < 1e-10);
    }

    #[test]
    fn diagonal_matrix() {
        let a = Mat::from_fn(4, 4, |i, j| if i == j { (i as f64) - 1.5 } else { 0.0 });
        let eig = sym_eig(&a);
        assert_eq!(eig.values, vec![-1.5, -0.5, 0.5, 1.5]);
        check_decomposition(&a, &eig, 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 1, 3.
        let a = Mat::from_vec(2, 2, vec![2., 1., 1., 2.]);
        let eig = sym_eig(&a);
        assert!((eig.values[0] - 1.0).abs() < 1e-12);
        assert!((eig.values[1] - 3.0).abs() < 1e-12);
        check_decomposition(&a, &eig, 1e-12);
    }

    #[test]
    fn random_matrices_various_sizes() {
        for (n, seed) in [(1, 1u64), (2, 2), (3, 3), (10, 4), (40, 5), (100, 6)] {
            let a = random_symmetric(n, seed);
            let eig = sym_eig(&a);
            check_decomposition(&a, &eig, 1e-8);
        }
    }

    #[test]
    fn trace_and_frobenius_invariants() {
        let a = random_symmetric(30, 7);
        let eig = sym_eig(&a);
        let trace: f64 = (0..30).map(|i| a[(i, i)]).sum();
        let sum: f64 = eig.values.iter().sum();
        assert!((trace - sum).abs() < 1e-9, "trace {trace} vs {sum}");
        let fro2: f64 = a.data().iter().map(|x| x * x).sum();
        let lam2: f64 = eig.values.iter().map(|x| x * x).sum();
        assert!((fro2 - lam2).abs() / fro2 < 1e-10);
    }

    #[test]
    fn tridiag_eig_matches_dense() {
        let n = 25;
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let diag: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let sub: Vec<f64> = (0..n - 1).map(|_| rng.normal()).collect();
        let mut dense = Mat::zeros(n, n);
        for i in 0..n {
            dense[(i, i)] = diag[i];
            if i + 1 < n {
                dense[(i + 1, i)] = sub[i];
                dense[(i, i + 1)] = sub[i];
            }
        }
        let e1 = tridiag_eig(&diag, &sub);
        let e2 = sym_eig(&dense);
        for j in 0..n {
            assert!((e1.values[j] - e2.values[j]).abs() < 1e-10);
        }
        check_decomposition(&dense, &e1, 1e-9);
    }

    #[test]
    fn laplacian_tridiagonal_has_known_spectrum() {
        // 1-D Dirichlet Laplacian: λ_k = 2 - 2 cos(kπ/(n+1)).
        let n = 50;
        let diag = vec![2.0; n];
        let sub = vec![-1.0; n - 1];
        let eig = tridiag_eig(&diag, &sub);
        for k in 1..=n {
            let expect = 2.0 - 2.0 * (k as f64 * std::f64::consts::PI / (n as f64 + 1.0)).cos();
            assert!(
                (eig.values[k - 1] - expect).abs() < 1e-10,
                "k={k} got {} want {expect}",
                eig.values[k - 1]
            );
        }
    }

    #[test]
    fn sym_eig_into_reuses_storage_bit_for_bit() {
        let mut out = SymEig {
            values: vec![],
            vectors: Mat::zeros(0, 0),
        };
        for seed in [11u64, 12, 13] {
            let a = random_symmetric(18, seed);
            sym_eig_into(&a, &mut out);
            let fresh = sym_eig(&a);
            assert_eq!(out.values, fresh.values);
            assert_eq!(out.vectors, fresh.vectors);
        }
    }

    #[test]
    fn generalized_reduces_to_standard_for_identity_mass() {
        let a = random_symmetric(16, 21);
        let m = Mat::eye(16);
        let gen = sym_eig_generalized(&a, &m);
        let std = sym_eig(&a);
        for j in 0..16 {
            assert!((gen.values[j] - std.values[j]).abs() < 1e-10);
        }
    }

    #[test]
    fn generalized_pencil_residuals_and_m_orthonormality() {
        let n = 14;
        let a = random_symmetric(n, 22);
        // SPD mass: Mᵀ M + I from a random square factor.
        let r = random_symmetric(n, 23);
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    s += r[(k, i)] * r[(k, j)];
                }
                m[(i, j)] = s;
            }
        }
        let eig = sym_eig_generalized(&a, &m);
        // A x = λ M x for every pair.
        for j in 0..n {
            let x = eig.vectors.col(j);
            for i in 0..n {
                let mut ax = 0.0;
                let mut mx = 0.0;
                for k in 0..n {
                    ax += a[(i, k)] * x[k];
                    mx += m[(i, k)] * x[k];
                }
                let err = (ax - eig.values[j] * mx).abs();
                assert!(err < 1e-8, "pencil residual {err} at pair {j}");
            }
        }
        // M-orthonormal columns: Xᵀ M X = I.
        for p in 0..n {
            for q in 0..n {
                let xp = eig.vectors.col(p);
                let xq = eig.vectors.col(q);
                let mut s = 0.0;
                for i in 0..n {
                    let mut mxq = 0.0;
                    for k in 0..n {
                        mxq += m[(i, k)] * xq[k];
                    }
                    s += xp[i] * mxq;
                }
                let want = if p == q { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-9, "XᵀMX[{p},{q}] = {s}");
            }
        }
        // Ascending order.
        for j in 1..n {
            assert!(eig.values[j] >= eig.values[j - 1] - 1e-12);
        }
    }

    #[test]
    fn empty_and_single() {
        let e = sym_eig(&Mat::zeros(0, 0));
        assert!(e.values.is_empty());
        let a = Mat::from_vec(1, 1, vec![4.2]);
        let e = sym_eig(&a);
        assert_eq!(e.values, vec![4.2]);
    }
}
