//! SCSF — the paper's contribution (§3): sort the problem set, then solve
//! it as a warm-started sequence with ChFSI.
//!
//! `SCSF = TruncatedFFT-sort ∘ (ChFSI warm-started from the previous
//! problem's eigenpairs)`. Setting [`crate::sort::SortMethod::None`]
//! gives the paper's "SCSF w/o sort" ablation; a fresh random start per
//! problem (no warm start at all) is the plain ChFSI baseline.

use super::chebyshev::{self, FilterBackend};
use super::chfsi::{self, ChfsiOptions, Escalation, Recycling};
use super::op::{OpTag, SpectralOp};
use super::solver::Workspace;
use super::{EigResult, RecycleSpace, SolveStats, WarmStart};
use crate::linalg::symeig::sym_eig;
use crate::linalg::Mat;
use crate::operators::Problem;
use crate::sort::{self, SortMethod, SortOutcome};
use crate::sparse::CsrMatrix;

/// Options for a sequence solve.
#[derive(Debug, Clone, Copy)]
pub struct ScsfOptions {
    /// Per-problem ChFSI options.
    pub chfsi: ChfsiOptions,
    /// Sorting strategy (paper default: truncated FFT with `p₀ = 20`).
    pub sort: SortMethod,
    /// Chain warm starts (`false` → every problem starts cold, i.e. the
    /// plain ChFSI baseline run over the same sequence).
    pub warm_start: bool,
}

impl ScsfOptions {
    /// Paper defaults: truncated-FFT sort (p₀=20), warm starts on.
    pub fn paper_default(chfsi: ChfsiOptions) -> Self {
        Self {
            chfsi,
            sort: SortMethod::TruncatedFft { p0: 20 },
            warm_start: true,
        }
    }
}

/// Health of one supervised record — what the dataset manifest's
/// `status` field carries (absent ⇔ `Ok`, the overwhelmingly common
/// case).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SolveStatus {
    /// First attempt converged with finite pairs — the historical path.
    #[default]
    Ok,
    /// The record holds validated pairs, but the escalation ladder had
    /// to retry / fall back / degrade the transform to get them.
    Retried,
    /// No rung produced acceptable pairs (or the worker panicked /
    /// timed out): the record carries no eigenpairs (`l = 0`), only its
    /// identity and a `fault` class, and the warm chain restarts cold
    /// after it.
    Quarantined,
}

impl SolveStatus {
    /// Manifest/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            SolveStatus::Ok => "ok",
            SolveStatus::Retried => "retried",
            SolveStatus::Quarantined => "quarantined",
        }
    }

    /// Parse a manifest/CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "ok" => Some(SolveStatus::Ok),
            "retried" => Some(SolveStatus::Retried),
            "quarantined" => Some(SolveStatus::Quarantined),
            _ => None,
        }
    }
}

/// Outcome of a supervised chain solve
/// ([`Chain::solve_next_supervised`]): the (possibly best-effort empty)
/// result plus the record health and fault class the manifest stores.
#[derive(Debug)]
pub struct Supervised {
    /// The accepted eigenpairs — empty (`vectors` is `n×0`) when
    /// `status` is [`SolveStatus::Quarantined`].
    pub result: EigResult,
    /// Record health.
    pub status: SolveStatus,
    /// Fault class (`""` when none): `nonconvergence`, `numeric`,
    /// `factorization` — the pipeline adds `panic` and `timeout`.
    pub fault: String,
}

impl Supervised {
    /// A quarantine outcome for an `n`-dimensional problem: no pairs,
    /// the given fault class, and whatever stats the failed attempts
    /// accumulated.
    pub fn quarantined(n: usize, fault: &str, mut stats: SolveStats) -> Self {
        stats.converged = false;
        Self {
            result: EigResult {
                values: Vec::new(),
                vectors: Mat::zeros(n, 0),
                residuals: Vec::new(),
                stats,
            },
            status: SolveStatus::Quarantined,
            fault: fault.to_string(),
        }
    }
}

/// Largest `n` the escalation ladder's dense `sym_eig` fallback rung
/// accepts — an O(n³) last resort that must never fire on problems
/// where it would dwarf the iterative solve budget.
const DENSE_FALLBACK_MAX_N: usize = 2048;

/// Fold a failed attempt's counters into an accumulator so the accepted
/// (or quarantined) record prices the *whole* supervised solve, keeping
/// the `Σ degree·count == filter_matvecs` histogram invariant across
/// retries.
fn absorb_stats(into: &mut SolveStats, other: &SolveStats) {
    into.iterations += other.iterations;
    into.matvecs += other.matvecs;
    into.filter_matvecs += other.filter_matvecs;
    into.f32_matvecs += other.f32_matvecs;
    into.promotions += other.promotions;
    into.deflated_cols += other.deflated_cols;
    into.recycle_matvecs += other.recycle_matvecs;
    super::merge_degree_hist(&mut into.degree_hist, &other.degree_hist);
    into.flops += other.flops;
    into.filter_flops += other.filter_flops;
    into.secs += other.secs;
    into.filter_secs += other.filter_secs;
    into.qr_secs += other.qr_secs;
    into.rr_secs += other.rr_secs;
    into.resid_secs += other.resid_secs;
    into.factor_secs += other.factor_secs;
    into.trisolve_count += other.trisolve_count;
}

/// Result of a sequence solve.
#[derive(Debug)]
pub struct SequenceResult {
    /// Per-problem results, in *solve order*.
    pub results: Vec<EigResult>,
    /// The solve order (indices into the input problem slice).
    pub order: Vec<usize>,
    /// Sorting cost breakdown.
    pub sort: SortOutcome,
    /// Inverse permutation: `inv[id]` is the solve position of problem
    /// `id` — makes [`Self::by_problem_id`] O(1) instead of a linear
    /// scan per lookup.
    inv: Vec<usize>,
}

impl SequenceResult {
    /// Assemble from per-position results and the sort outcome that
    /// ordered them (precomputes the inverse permutation).
    pub fn new(results: Vec<EigResult>, sort: SortOutcome) -> Self {
        assert_eq!(results.len(), sort.order.len());
        let order = sort.order.clone();
        let mut inv = vec![usize::MAX; order.len()];
        for (pos, &id) in order.iter().enumerate() {
            inv[id] = pos;
        }
        Self {
            results,
            order,
            sort,
            inv,
        }
    }

    /// Result for the problem with original index `id`.
    pub fn by_problem_id(&self, id: usize) -> &EigResult {
        let pos = *self.inv.get(id).expect("unknown problem id");
        assert_ne!(pos, usize::MAX, "unknown problem id");
        &self.results[pos]
    }

    /// Mean wall-clock seconds per solve (the paper's headline metric).
    pub fn avg_secs(&self) -> f64 {
        self.results.iter().map(|r| r.stats.secs).sum::<f64>() / self.results.len() as f64
    }

    /// Mean outer iterations per solve.
    pub fn avg_iterations(&self) -> f64 {
        self.results.iter().map(|r| r.stats.iterations as f64).sum::<f64>()
            / self.results.len() as f64
    }

    /// Total flops across the sequence (Mflop).
    pub fn total_mflops(&self) -> f64 {
        self.results.iter().map(|r| r.stats.flops as f64).sum::<f64>() / 1e6
    }

    /// Filter-only flops across the sequence (Mflop).
    pub fn filter_mflops(&self) -> f64 {
        self.results
            .iter()
            .map(|r| r.stats.filter_flops as f64)
            .sum::<f64>()
            / 1e6
    }

    /// Total `A·x` products across the sequence (all solver phases).
    pub fn total_matvecs(&self) -> usize {
        self.results.iter().map(|r| r.stats.matvecs).sum()
    }

    /// `A·x` products spent inside the Chebyshev filter — the quantity
    /// the adaptive degree schedule cuts versus fixed degree-20.
    pub fn filter_matvecs(&self) -> usize {
        self.results.iter().map(|r| r.stats.filter_matvecs).sum()
    }

    /// Filter `A·x` products that ran in f32 across the sequence
    /// (subset of [`Self::filter_matvecs`]; nonzero only under
    /// `precision: mixed`).
    pub fn f32_matvecs(&self) -> usize {
        self.results.iter().map(|r| r.stats.f32_matvecs).sum()
    }

    /// Columns promoted from the f32 lane back to f64 across the
    /// sequence.
    pub fn promotions(&self) -> usize {
        self.results.iter().map(|r| r.stats.promotions).sum()
    }

    /// Columns deflated out of filter sweeps across the sequence —
    /// seed-locked inherited pairs plus per-sweep parked columns.
    /// Nonzero only under `recycling: deflate`.
    pub fn deflated_cols(&self) -> usize {
        self.results.iter().map(|r| r.stats.deflated_cols).sum()
    }

    /// `A·x` products the recycling layer itself spent (residual
    /// pricing that deflation alone caused, plus thick-restart
    /// compression); subset of [`Self::total_matvecs`].
    pub fn recycle_matvecs(&self) -> usize {
        self.results.iter().map(|r| r.stats.recycle_matvecs).sum()
    }

    /// Merged per-column filter-degree histogram across the sequence
    /// (`hist[m]` = columns filtered at degree `m`).
    pub fn degree_hist(&self) -> Vec<usize> {
        let mut hist: Vec<usize> = Vec::new();
        for r in &self.results {
            super::merge_degree_hist(&mut hist, &r.stats.degree_hist);
        }
        hist
    }

    /// True if every solve converged.
    pub fn all_converged(&self) -> bool {
        self.results.iter().all(|r| r.stats.converged)
    }
}

/// Solve a problem set with SCSF using the native filter backend
/// selected by `opts.chfsi.filter_backend` (CSR by default).
pub fn solve_sequence(problems: &[Problem], opts: &ScsfOptions) -> SequenceResult {
    match opts.chfsi.filter_backend {
        super::chebyshev::FilterBackendKind::Csr => {
            let mut backend = super::chebyshev::NativeFilter::new();
            solve_sequence_with_backend(problems, opts, &mut backend)
        }
        super::chebyshev::FilterBackendKind::Sell => {
            let mut backend = super::chebyshev::SellFilter::new();
            solve_sequence_with_backend(problems, opts, &mut backend)
        }
    }
}

/// Solve a problem set with SCSF on an explicit filter backend (used by
/// the PJRT/XLA integration and by the pipeline workers).
///
/// One [`Workspace`] is shared across the whole warm-started sequence —
/// this is the sequence-level payoff of the zero-alloc refactor: after
/// the first problem, solver iterations run entirely in reused buffers.
pub fn solve_sequence_with_backend(
    problems: &[Problem],
    opts: &ScsfOptions,
    backend: &mut dyn FilterBackend,
) -> SequenceResult {
    let mut ws = Workspace::new(opts.chfsi.threads);
    solve_sequence_in(problems, opts, backend, &mut ws)
}

/// [`solve_sequence_with_backend`] inside a caller-owned [`Workspace`]
/// (pipeline shard workers hold one workspace for their whole lifetime).
pub fn solve_sequence_in(
    problems: &[Problem],
    opts: &ScsfOptions,
    backend: &mut dyn FilterBackend,
    ws: &mut Workspace,
) -> SequenceResult {
    assert!(!problems.is_empty());
    let sort = sort::sort_problems(problems, opts.sort);
    let mut results = Vec::with_capacity(problems.len());
    let mut chain = Chain::new();
    for &idx in &sort.order {
        results.push(chain.solve_next_for_mass(
            &problems[idx].family,
            &problems[idx].matrix,
            problems[idx].mass.as_ref(),
            opts,
            backend,
            ws,
        ));
    }
    SequenceResult::new(results, sort)
}

/// A warm-started solve chain — the unit the pipeline's solve stage
/// runs: each similarity run is one `Chain`, optionally seeded by the
/// previous run's tail eigenpairs (the scheduler's boundary handoff).
///
/// The chain carries the warm start between consecutive solves and
/// counts cold starts, so warm-start hit rate is a first-class, measured
/// quantity rather than an emergent property of the loop.
///
/// Chains are *family-aware*: a warm start is only meaningful between
/// problems of one operator family (and one matrix dimension), so
/// [`Chain::solve_next_for`] resets the carried subspace whenever the
/// family tag or the dimension changes. The pipeline's scheduler already
/// keeps runs inside family boundaries; the reset is the chain-level
/// guarantee for callers that sequence mixed problems directly.
#[derive(Debug, Default)]
pub struct Chain {
    warm: Option<WarmStart>,
    /// Family tag of the last solve (what the reset compares against).
    family: Option<std::sync::Arc<str>>,
    /// Operator tag (problem kind + shift) the carried subspace was
    /// solved under — seam handoffs must agree on it
    /// ([`Chain::try_adopt`]); `None` until something is carried.
    tag: Option<OpTag>,
    /// Solves that started cold (no inherited subspace).
    pub cold_starts: usize,
    /// Solves that inherited a subspace (chained or handed off).
    pub warm_solves: usize,
    /// Times the carried subspace was dropped because the family (or
    /// matrix dimension) changed mid-chain.
    pub family_resets: usize,
}

impl Chain {
    /// A chain with no inherited state: its first solve is cold.
    pub fn new() -> Self {
        Self::default()
    }

    /// Adopt a boundary handoff: the next solve warm-starts from
    /// `tail` (the previous run's final eigenpairs).
    pub fn adopt(&mut self, tail: WarmStart) {
        self.warm = Some(tail);
    }

    /// [`Chain::adopt`] with the agreement checks a seam handoff needs:
    /// the tail must come from the same operator family, matrix
    /// dimension, *and operator mode* ([`OpTag`]: problem kind plus
    /// shift-invert σ) the chain is about to solve. On a mismatch the
    /// tail is *not* adopted and the error names the disagreement —
    /// callers (the pipeline's run handoff) wrap it with the run ids
    /// involved — instead of silently carrying a shape- or
    /// coordinate-mismatched warm start: a shift-inverted or
    /// `Wᵀ`-coordinate basis is poison to a plain chain and vice versa.
    pub fn try_adopt(
        &mut self,
        family: &std::sync::Arc<str>,
        n: usize,
        tag: OpTag,
        tail_family: &std::sync::Arc<str>,
        tail_tag: OpTag,
        tail: WarmStart,
    ) -> Result<(), String> {
        if tail_family.as_ref() != family.as_ref() {
            return Err(format!(
                "family mismatch (tail from family '{tail_family}', chain solves '{family}')"
            ));
        }
        if tail.vectors.rows() != n {
            return Err(format!(
                "dimension mismatch (tail has n={}, chain solves n={n})",
                tail.vectors.rows()
            ));
        }
        if tail_tag.kind != tag.kind {
            return Err(format!(
                "problem-type mismatch (tail solved '{}', chain solves '{}')",
                tail_tag.kind.name(),
                tag.kind.name()
            ));
        }
        if tail_tag.shift != tag.shift {
            let fmt = |s: Option<f64>| match s {
                Some(v) => format!("shift_invert:{v}"),
                None => "none".to_string(),
            };
            return Err(format!(
                "shift mismatch (tail solved under transform '{}', chain solves under '{}')",
                fmt(tail_tag.shift),
                fmt(tag.shift)
            ));
        }
        self.family = Some(family.clone());
        self.tag = Some(tag);
        self.warm = Some(tail);
        Ok(())
    }

    /// Drop any carried subspace and family tag: the next solve starts
    /// cold (the explicit family-boundary reset).
    pub fn reset(&mut self) {
        self.warm = None;
        self.family = None;
        self.tag = None;
    }

    /// [`Chain::solve_next`] with a family tag: if the tag (or the
    /// matrix dimension) differs from the previous solve's, the carried
    /// subspace is dropped first — warm starts never cross a family
    /// boundary. Identical to `solve_next` within one family.
    pub fn solve_next_for(
        &mut self,
        family: &std::sync::Arc<str>,
        a: &crate::sparse::CsrMatrix,
        opts: &ScsfOptions,
        backend: &mut dyn FilterBackend,
        ws: &mut Workspace,
    ) -> EigResult {
        self.solve_next_for_mass(family, a, None, opts, backend, ws)
    }

    /// [`Chain::solve_next_for`] with an optional consistent mass
    /// matrix — the generalized path (`problem: generalized` in
    /// `opts.chfsi`) factors `M = WWᵀ` per solve and works in operator
    /// coordinates; `mass` is ignored for standard problems.
    pub fn solve_next_for_mass(
        &mut self,
        family: &std::sync::Arc<str>,
        a: &crate::sparse::CsrMatrix,
        mass: Option<&crate::sparse::CsrMatrix>,
        opts: &ScsfOptions,
        backend: &mut dyn FilterBackend,
        ws: &mut Workspace,
    ) -> EigResult {
        let family_changed = self
            .family
            .as_ref()
            .is_some_and(|prev| prev.as_ref() != family.as_ref());
        let dim_changed = self
            .warm
            .as_ref()
            .is_some_and(|w| w.vectors.rows() != a.rows());
        // Only an actually-carried subspace can be dropped; a family
        // switch with nothing carried (e.g. the cold-start ablation) is
        // not a reset.
        if self.warm.is_some() && (family_changed || dim_changed) {
            self.warm = None;
            self.family_resets += 1;
        }
        self.family = Some(family.clone());
        self.solve_next_mass(a, mass, opts, backend, ws)
    }

    /// True if the *next* solve would start cold — the chain's
    /// cold-start detector (first solve of a run with no handoff).
    pub fn next_is_cold(&self, opts: &ScsfOptions) -> bool {
        !(opts.warm_start && self.warm.is_some())
    }

    /// Solve the next problem of the chain, inheriting the current warm
    /// start (if any, and if `opts.warm_start`) and capturing the
    /// result's eigenpairs for the solve after it.
    ///
    /// The carried [`WarmStart`] also transports the predecessor's
    /// spectral upper bound ([`WarmStart::upper`]): under the adaptive
    /// filter schedule a warm solve seeds its interval from it plus a
    /// cheap bound refresh instead of a full Lanczos estimate. Family
    /// or dimension resets drop the bound together with the subspace.
    pub fn solve_next(
        &mut self,
        a: &crate::sparse::CsrMatrix,
        opts: &ScsfOptions,
        backend: &mut dyn FilterBackend,
        ws: &mut Workspace,
    ) -> EigResult {
        self.solve_next_mass(a, None, opts, backend, ws)
    }

    /// [`Chain::solve_next`] with an optional mass matrix: the operator
    /// (plain, generalized, or shift-inverted — per `opts.chfsi`) is
    /// built here, and if its [`OpTag`] differs from the one the carried
    /// subspace was solved under, the subspace is dropped first — the
    /// basis lives in mode-specific coordinates and must not leak across
    /// a transform boundary.
    pub fn solve_next_mass(
        &mut self,
        a: &crate::sparse::CsrMatrix,
        mass: Option<&crate::sparse::CsrMatrix>,
        opts: &ScsfOptions,
        backend: &mut dyn FilterBackend,
        ws: &mut Workspace,
    ) -> EigResult {
        let op = SpectralOp::build(a, mass, opts.chfsi.problem, opts.chfsi.transform)
            .unwrap_or_else(|e| panic!("operator construction failed: {e}"));
        self.align_tag(&op);
        let cold = self.next_is_cold(opts);
        if cold {
            self.cold_starts += 1;
        } else {
            self.warm_solves += 1;
        }
        let init = if cold { None } else { self.warm.as_ref() };
        let mut r = chfsi::solve_op_in(&op, &opts.chfsi, init, backend, ws);
        self.commit_warm(&mut r, a, opts);
        r
    }

    /// Drop the carried subspace if it was solved under a different
    /// operator mode than `op`, then record `op`'s tag — the basis lives
    /// in mode-specific coordinates and must not leak across a
    /// transform boundary.
    fn align_tag(&mut self, op: &SpectralOp) {
        if self.warm.is_some() && self.tag.is_some_and(|t| t != op.tag()) {
            self.warm = None;
            self.family_resets += 1;
        }
        self.tag = Some(op.tag());
    }

    /// Capture `r`'s eigenpairs as the next solve's warm start (when
    /// `opts.warm_start`). Under `recycling: deflate` the chain also
    /// carries the recycle space forward: fold this solve's pairs in,
    /// compress via thick restart when it overflows `recycle_dim`, and
    /// charge the compression matvecs to this solve's counters.
    fn commit_warm(&mut self, r: &mut EigResult, a: &CsrMatrix, opts: &ScsfOptions) {
        if opts.warm_start {
            let recycle = if opts.chfsi.recycling == Recycling::Deflate {
                let prev = self.warm.take().and_then(|w| w.recycle);
                let (space, extra) = update_recycle_space(prev, r, a, &opts.chfsi);
                r.stats.matvecs += extra;
                r.stats.recycle_matvecs += extra;
                space
            } else {
                None
            };
            let mut next = r.as_warm_start();
            next.recycle = recycle;
            self.warm = Some(next);
        }
    }

    /// [`Chain::solve_next_for_mass`] under the solve supervision layer:
    /// instead of panicking on operator-construction failure or
    /// returning unconverged pairs, every problem ends in a structured
    /// [`Supervised`] outcome.
    ///
    /// On a clean, converging solve this is bit-for-bit the historical
    /// path (the first attempt *is* `solve_next_for_mass`'s solve).
    /// Otherwise, under `escalation: ladder`:
    ///
    /// 1. **Retry rungs** (`max_retries` of them): degree/guard bump
    ///    keeping the warm start, then a cold restart with a bigger
    ///    bump and a reseeded random block.
    /// 2. **Dense fallback**: plain operators with
    ///    `n ≤ 2048` fall back to [`sym_eig`].
    /// 3. **Factorization degrade**: if the shift-inverted operator
    ///    cannot be factored (σ on the pencil spectrum), the record is
    ///    solved on the extremal (untransformed) path instead, with
    ///    `fault: factorization`.
    /// 4. **Quarantine**: anything still failing (or non-finite) yields
    ///    an empty record with a fault class, and the chain restarts
    ///    cold — downstream solves and seam handoffs proceed.
    ///
    /// Failed attempts' work is absorbed into the final record's
    /// [`SolveStats`], with the ladder charged to
    /// `retries`/`escalations`/`fallback`.
    #[allow(clippy::too_many_arguments)]
    pub fn solve_next_supervised(
        &mut self,
        family: &std::sync::Arc<str>,
        a: &crate::sparse::CsrMatrix,
        mass: Option<&crate::sparse::CsrMatrix>,
        opts: &ScsfOptions,
        backend: &mut dyn FilterBackend,
        ws: &mut Workspace,
    ) -> Supervised {
        // Family/dimension reset — same policy as solve_next_for_mass.
        let family_changed = self
            .family
            .as_ref()
            .is_some_and(|prev| prev.as_ref() != family.as_ref());
        let dim_changed = self
            .warm
            .as_ref()
            .is_some_and(|w| w.vectors.rows() != a.rows());
        if self.warm.is_some() && (family_changed || dim_changed) {
            self.warm = None;
            self.family_resets += 1;
        }
        self.family = Some(family.clone());

        let chf = opts.chfsi;
        // Operator construction is fallible here: an LDLᵀ breakdown of
        // `A − σM` degrades this record to the extremal path (the chain
        // keeps carrying its shift-invert subspace for later records);
        // a mass-factorization failure has no degraded form and
        // quarantines outright.
        let (op, degraded) = match SpectralOp::build(a, mass, chf.problem, chf.transform) {
            Ok(op) => (op, false),
            Err(_) if !chf.transform.is_none() => {
                match SpectralOp::build(a, mass, chf.problem, super::op::Transform::None) {
                    Ok(op) => (op, true),
                    Err(_) => {
                        self.warm = None;
                        return Supervised::quarantined(
                            a.rows(),
                            "factorization",
                            SolveStats::default(),
                        );
                    }
                }
            }
            Err(_) => {
                self.warm = None;
                return Supervised::quarantined(a.rows(), "factorization", SolveStats::default());
            }
        };
        // A perturbed-refactor recovery kept the shift-invert operator
        // usable but not pristine — surface it as a retried record.
        let recovered = op.recovered();
        if !degraded {
            self.align_tag(&op);
        }
        let cold = degraded || self.next_is_cold(opts);
        if cold {
            self.cold_starts += 1;
        } else {
            self.warm_solves += 1;
        }

        let ladder = chf.escalation == Escalation::Ladder;
        let budget = if ladder { chf.max_retries } else { 0 };
        let g0 = chf.block_width(op.n()).saturating_sub(chf.eig.n_eigs);
        let mut attempt = chf;
        let mut use_warm = !cold;
        let mut retries = 0usize;
        let mut escalations = 0usize;
        let mut spent = SolveStats::default();
        let mut last_numeric = false;
        let mut accepted: Option<EigResult> = None;
        let mut last_failed: Option<EigResult> = None;
        loop {
            let init = if use_warm { self.warm.as_ref() } else { None };
            let r = chfsi::solve_op_in(&op, &attempt, init, backend, ws);
            let finite = r.values.iter().all(|v| v.is_finite())
                && r.residuals.iter().all(|v| v.is_finite());
            if r.stats.converged && finite {
                accepted = Some(r);
                break;
            }
            last_numeric = !finite;
            if retries >= budget {
                last_failed = Some(r);
                break;
            }
            absorb_stats(&mut spent, &r.stats);
            retries += 1;
            escalations += 1;
            if retries == 1 {
                // Rung 1: more filter degree and a wider guard block,
                // warm start kept — the cheap fix for a too-shallow
                // filter or a cluster straddling the block edge.
                attempt.degree = chf.degree + (chf.degree / 2).max(4);
                attempt.guard = Some(g0 + 4);
            } else {
                // Rung 2+: the inherited subspace may itself be the
                // problem — discard it and cold-restart from a reseeded
                // random block with a still-bigger bump.
                use_warm = false;
                attempt.degree = chf.degree * 2;
                attempt.guard = Some(g0 + 8);
                attempt.eig.seed = chf
                    .eig
                    .seed
                    .wrapping_add(0x9e37_79b9_7f4a_7c15u64.wrapping_mul(retries as u64));
            }
        }

        // Last rung: dense fallback for small plain operators.
        let mut fallback = false;
        if accepted.is_none() && ladder && op.is_plain() && op.n() <= DENSE_FALLBACK_MAX_N {
            if let Some(r) = last_failed.take() {
                absorb_stats(&mut spent, &r.stats);
            }
            retries += 1;
            escalations += 1;
            let t0 = std::time::Instant::now();
            let dense = sym_eig(&a.to_dense());
            let l = chf.eig.n_eigs.min(dense.values.len());
            let values = dense.values[..l].to_vec();
            let vectors = dense.vectors.cols_range(0, l);
            let stats = SolveStats {
                secs: t0.elapsed().as_secs_f64(),
                ..SolveStats::default()
            };
            let r = EigResult::finalize(a, values, vectors, stats, chf.eig.tol);
            let finite = r.values.iter().all(|v| v.is_finite())
                && r.residuals.iter().all(|v| v.is_finite());
            if r.stats.converged && finite {
                fallback = true;
                accepted = Some(r);
            } else {
                last_numeric = !finite;
                last_failed = Some(r);
            }
        }

        match accepted {
            Some(mut r) => {
                absorb_stats(&mut r.stats, &spent);
                r.stats.retries = retries;
                r.stats.escalations = escalations;
                r.stats.fallback = fallback;
                if !degraded {
                    self.commit_warm(&mut r, a, opts);
                }
                let status = if retries > 0 || degraded || recovered {
                    SolveStatus::Retried
                } else {
                    SolveStatus::Ok
                };
                Supervised {
                    result: r,
                    status,
                    fault: if degraded || recovered {
                        "factorization".into()
                    } else {
                        String::new()
                    },
                }
            }
            None => {
                if !ladder && !last_numeric {
                    // escalation: off — historical behavior: the
                    // best-effort unconverged pairs are the record
                    // (finalize already set `converged = false`); only
                    // the NaN/Inf guard quarantines.
                    let mut r = last_failed.expect("a zero-budget loop records its attempt");
                    if !degraded {
                        self.commit_warm(&mut r, a, opts);
                    }
                    let status = if degraded || recovered {
                        SolveStatus::Retried
                    } else {
                        SolveStatus::Ok
                    };
                    return Supervised {
                        result: r,
                        status,
                        fault: if degraded || recovered {
                            "factorization".into()
                        } else {
                            String::new()
                        },
                    };
                }
                // Every rung failed: quarantine the record and publish
                // a cold seam so downstream solves are unperturbed.
                let mut stats = last_failed.map(|r| r.stats).unwrap_or_default();
                absorb_stats(&mut stats, &spent);
                stats.retries = retries;
                stats.escalations = escalations;
                self.warm = None;
                let fault = if last_numeric { "numeric" } else { "nonconvergence" };
                Supervised::quarantined(a.rows(), fault, stats)
            }
        }
    }

    /// The chain's tail eigenpairs — what a boundary handoff publishes
    /// to the next run (`None` if nothing was solved warm-capably).
    pub fn tail(&self) -> Option<&WarmStart> {
        self.warm.as_ref()
    }

    /// Consume the chain, yielding the tail for handoff.
    pub fn into_tail(self) -> Option<WarmStart> {
        self.warm
    }
}

/// Fold a deflating solve's converged pairs into the chain's carried
/// [`RecycleSpace`] and bound its size (DESIGN.md §Subspace-recycling).
///
/// The refreshed space leads with the current solve's eigenpairs (the
/// freshest directions); carried directions join behind them after a
/// 2×DGKS re-orthogonalization, dropped when the new pairs already span
/// them. When the combined basis exceeds `recycle_dim` (auto: twice the
/// iterate-block width) a thick restart runs: Rayleigh–Ritz against the
/// *current* operator, then the `recycle_keep` (auto: block width) Ritz
/// pairs most aligned with the target window survive — pairs whose
/// relative residual stays under the staleness bar
/// ([`chebyshev::guard_target`] of the solve tolerance) rank ahead of
/// stale ones, ascending in Ritz value within each class. The basis
/// stays f64 end to end regardless of the filter precision policy.
///
/// Returns the refreshed space plus the `A·x` products the compression
/// spent (`basis.cols()` when a thick restart ran, zero otherwise) so
/// the caller can charge them to the solve's matvec counters.
fn update_recycle_space(
    prev: Option<RecycleSpace>,
    r: &EigResult,
    a: &CsrMatrix,
    opts: &ChfsiOptions,
) -> (Option<RecycleSpace>, usize) {
    let n = a.rows();
    if r.vectors.rows() != n || r.vectors.cols() == 0 {
        return (prev.filter(|s| s.basis.rows() == n), 0);
    }
    let block = opts.block_width(n);
    let dim_cap = if opts.recycle_dim == 0 {
        2 * block
    } else {
        opts.recycle_dim
    }
    .max(1);
    let keep = if opts.recycle_keep == 0 {
        block
    } else {
        opts.recycle_keep
    }
    .clamp(1, dim_cap);

    let fresh = r.vectors.cols().min(r.values.len());
    let mut cols: Vec<Vec<f64>> = (0..fresh).map(|j| r.vectors.col(j)).collect();
    let mut vals: Vec<f64> = r.values[..fresh].to_vec();
    if let Some(prev) = prev.as_ref().filter(|s| s.basis.rows() == n) {
        let old = prev.basis.cols().min(prev.values.len());
        for j in 0..old {
            let mut v = prev.basis.col(j);
            for _ in 0..2 {
                for q in &cols {
                    let d: f64 = q.iter().zip(&v).map(|(qi, vi)| qi * vi).sum();
                    for (vi, qi) in v.iter_mut().zip(q) {
                        *vi -= d * qi;
                    }
                }
            }
            let nrm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if nrm > 1e-8 {
                for x in &mut v {
                    *x /= nrm;
                }
                cols.push(v);
                vals.push(prev.values[j]);
            }
        }
    }

    let m = cols.len();
    let basis = Mat::from_fn(n, m, |i, j| cols[j][i]);
    if m <= dim_cap {
        return (Some(RecycleSpace { basis, values: vals }), 0);
    }

    // Thick restart against the current operator: W = A·B, G = BᵀW,
    // sym_eig(G) → (μ, Y); Ritz pairs (μᵢ, B·yᵢ), residuals ‖W·yᵢ − μᵢB·yᵢ‖.
    let mut w = Mat::zeros(0, 0);
    a.spmm_into(&basis, &mut w, opts.threads.max(1));
    let g = basis.t_matmul(&w);
    let eig = sym_eig(&g);
    let by = basis.matmul(&eig.vectors);
    let wy = w.matmul(&eig.vectors);
    let stale_bar = chebyshev::guard_target(opts.eig.tol);
    let res: Vec<f64> = (0..m)
        .map(|i| {
            let mu = eig.values[i];
            let mut r2 = 0.0;
            for row in 0..n {
                let d = wy[(row, i)] - mu * by[(row, i)];
                r2 += d * d;
            }
            r2.sqrt() / mu.abs().max(1.0)
        })
        .collect();
    let mut kept: Vec<usize> = (0..m).filter(|&i| res[i] <= stale_bar).collect();
    kept.extend((0..m).filter(|&i| res[i] > stale_bar));
    kept.truncate(keep);
    let mut kb = Mat::zeros(0, 0);
    kb.gather_cols_into(&by, &kept);
    let kv: Vec<f64> = kept.iter().map(|&i| eig.values[i]).collect();
    (Some(RecycleSpace { basis: kb, values: kv }), m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eig::EigOptions;
    use crate::linalg::symeig::sym_eig;
    use crate::operators::{self, GenOptions, OperatorKind};

    fn opts(l: usize, tol: f64) -> ScsfOptions {
        ScsfOptions::paper_default(ChfsiOptions::from_eig(&EigOptions {
            n_eigs: l,
            tol,
            max_iters: 300,
            seed: 0,
        }))
    }

    fn dataset(n: usize, seed: u64) -> Vec<operators::Problem> {
        operators::generate(
            OperatorKind::Helmholtz,
            GenOptions {
                grid: 10,
                ..Default::default()
            },
            n,
            seed,
        )
    }

    #[test]
    fn sequence_solves_every_problem_correctly() {
        let ps = dataset(4, 1);
        let seq = solve_sequence(&ps, &opts(5, 1e-8));
        assert!(seq.all_converged());
        assert_eq!(seq.results.len(), 4);
        for (pos, &pid) in seq.order.iter().enumerate() {
            let want = sym_eig(&ps[pid].matrix.to_dense());
            for (got, w) in seq.results[pos].values.iter().zip(&want.values[..5]) {
                assert!(
                    (got - w).abs() / w.abs().max(1.0) < 1e-6,
                    "problem {pid}: {got} vs {w}"
                );
            }
        }
    }

    #[test]
    fn by_problem_id_maps_back() {
        let ps = dataset(5, 2);
        let seq = solve_sequence(&ps, &opts(4, 1e-8));
        for pid in 0..5 {
            let r = seq.by_problem_id(pid);
            let want = sym_eig(&ps[pid].matrix.to_dense());
            assert!((r.values[0] - want.values[0]).abs() / want.values[0] < 1e-6);
        }
    }

    #[test]
    fn by_problem_id_is_constant_time_over_large_sequences() {
        // Regression for the O(N) linear scan: 1k problems, a million
        // lookups. With the precomputed inverse permutation this is
        // milliseconds; the old per-lookup scan was ~1e9 comparisons.
        use crate::linalg::Mat;
        use crate::sort::SortOutcome;
        let n = 1000usize;
        // A deterministic nontrivial permutation (stride coprime to n).
        let order: Vec<usize> = (0..n).map(|t| (t * 7) % n).collect();
        let results: Vec<crate::eig::EigResult> = order
            .iter()
            .map(|&id| crate::eig::EigResult {
                values: vec![id as f64],
                vectors: Mat::zeros(1, 1),
                residuals: vec![0.0],
                stats: Default::default(),
            })
            .collect();
        let seq = SequenceResult::new(
            results,
            SortOutcome {
                order,
                fft_secs: 0.0,
                greedy_secs: 0.0,
                quality: 0.0,
            },
        );
        let t0 = std::time::Instant::now();
        let mut checksum = 0.0;
        for rep in 0..1000 {
            for id in 0..n {
                let r = seq.by_problem_id(id);
                debug_assert_eq!(r.values[0], id as f64);
                if rep == 0 {
                    assert_eq!(r.values[0], id as f64, "lookup maps to wrong result");
                }
                checksum += r.values[0];
            }
        }
        assert_eq!(checksum, 1000.0 * (n * (n - 1) / 2) as f64);
        // Generous even for debug builds with the O(1) lookup; the old
        // linear scan blows far past it.
        assert!(
            t0.elapsed().as_secs_f64() < 2.0,
            "1e6 lookups took {:.2}s — by_problem_id regressed to a scan?",
            t0.elapsed().as_secs_f64()
        );
    }

    #[test]
    fn chain_counts_cold_and_warm_solves() {
        let ps = dataset(3, 7);
        let o = opts(4, 1e-8);
        let mut backend = crate::eig::chebyshev::NativeFilter::new();
        let mut ws = Workspace::new(1);
        let mut chain = Chain::new();
        assert!(chain.next_is_cold(&o));
        for p in &ps {
            chain.solve_next(&p.matrix, &o, &mut backend, &mut ws);
        }
        assert_eq!(chain.cold_starts, 1);
        assert_eq!(chain.warm_solves, 2);
        let tail = chain.into_tail().expect("warm chain has a tail");

        // A handoff-seeded chain starts warm.
        let mut next = Chain::new();
        next.adopt(tail);
        assert!(!next.next_is_cold(&o));
        next.solve_next(&ps[0].matrix, &o, &mut backend, &mut ws);
        assert_eq!(next.cold_starts, 0);
        assert_eq!(next.warm_solves, 1);

        // warm_start=false forces every solve cold, even with a tail.
        let mut cold_opts = o;
        cold_opts.warm_start = false;
        let mut c = Chain::new();
        for p in &ps {
            c.solve_next(&p.matrix, &cold_opts, &mut backend, &mut ws);
        }
        assert_eq!(c.cold_starts, 3);
        assert_eq!(c.warm_solves, 0);
    }

    #[test]
    fn chain_resets_across_family_boundaries() {
        // A mixed sequence: warm starts must not leak across the family
        // switch (or across a dimension change), and the reset is
        // counted.
        let o = {
            let mut o = opts(3, 1e-8);
            o.sort = crate::sort::SortMethod::None;
            o
        };
        let gen_opts = GenOptions {
            grid: 8,
            ..Default::default()
        };
        let helm = operators::generate(OperatorKind::Helmholtz, gen_opts, 2, 3);
        let pois = operators::generate(OperatorKind::Poisson, gen_opts, 2, 4);
        let mut backend = crate::eig::chebyshev::NativeFilter::new();
        let mut ws = Workspace::new(1);
        let mut chain = Chain::new();
        for p in helm.iter().chain(&pois) {
            chain.solve_next_for(&p.family, &p.matrix, &o, &mut backend, &mut ws);
        }
        assert_eq!(chain.family_resets, 1, "one reset at the family switch");
        assert_eq!(chain.cold_starts, 2, "each family starts cold");
        assert_eq!(chain.warm_solves, 2);

        // Within one family, solve_next_for is exactly solve_next.
        let mut tagged = Chain::new();
        let mut plain = Chain::new();
        let mut r_tagged = Vec::new();
        let mut r_plain = Vec::new();
        for p in &helm {
            r_tagged.push(tagged.solve_next_for(&p.family, &p.matrix, &o, &mut backend, &mut ws));
            r_plain.push(plain.solve_next(&p.matrix, &o, &mut backend, &mut ws));
        }
        assert_eq!(tagged.family_resets, 0);
        for (a, b) in r_tagged.iter().zip(&r_plain) {
            assert_eq!(a.values, b.values);
            assert_eq!(a.vectors, b.vectors);
        }

        // An explicit reset also drops the carried subspace.
        let mut c = Chain::new();
        c.solve_next_for(&helm[0].family, &helm[0].matrix, &o, &mut backend, &mut ws);
        assert!(!c.next_is_cold(&o));
        c.reset();
        assert!(c.next_is_cold(&o));

        // Cold ablation: nothing is carried, so a family switch drops
        // nothing and the reset counter stays at zero.
        let mut cold_opts = o;
        cold_opts.warm_start = false;
        let mut cc = Chain::new();
        for p in helm.iter().chain(&pois) {
            cc.solve_next_for(&p.family, &p.matrix, &cold_opts, &mut backend, &mut ws);
        }
        assert_eq!(cc.family_resets, 0);
        assert_eq!(cc.cold_starts, 4);
    }

    #[test]
    fn mixed_family_sequence_solves_with_no_sort() {
        // solve_sequence over a mixed problem set (SortMethod::None —
        // cross-family keys are not comparable): every solve converges
        // and warm starts reset at the family boundary.
        let gen_opts = GenOptions {
            grid: 8,
            ..Default::default()
        };
        let mut ps = operators::generate(OperatorKind::Helmholtz, gen_opts, 2, 5);
        for (i, mut p) in operators::generate(OperatorKind::Poisson, gen_opts, 2, 6)
            .into_iter()
            .enumerate()
        {
            p.id = 2 + i;
            ps.push(p);
        }
        let mut o = opts(3, 1e-8);
        o.sort = crate::sort::SortMethod::None;
        let seq = solve_sequence(&ps, &o);
        assert!(seq.all_converged());
        for (pos, &pid) in seq.order.iter().enumerate() {
            let want = sym_eig(&ps[pid].matrix.to_dense());
            for (got, w) in seq.results[pos].values.iter().zip(&want.values[..3]) {
                assert!((got - w).abs() / w.abs().max(1.0) < 1e-6);
            }
        }
    }

    #[test]
    fn warm_chain_beats_cold_chain_on_similar_problems() {
        // The core SCSF claim (Table 17 shape): chained warm starts cut
        // iterations versus per-problem cold starts.
        let chain = operators::helmholtz::generate_perturbed_chain(
            GenOptions {
                grid: 10,
                ..Default::default()
            },
            6,
            0.05,
            3,
        );
        let mut o = opts(5, 1e-8);
        o.sort = crate::sort::SortMethod::None;
        let warm = solve_sequence(&chain, &o);
        let mut cold_opts = o;
        cold_opts.warm_start = false;
        let cold = solve_sequence(&chain, &cold_opts);
        assert!(warm.all_converged() && cold.all_converged());
        assert!(
            warm.avg_iterations() < cold.avg_iterations(),
            "warm {} cold {}",
            warm.avg_iterations(),
            cold.avg_iterations()
        );
        assert!(warm.total_mflops() < cold.total_mflops());
    }

    #[test]
    fn sorting_helps_on_iid_datasets() {
        // Table 3 shape: with-sort ≤ without-sort in filter flops on an
        // i.i.d. (unchained) dataset.
        let ps = dataset(10, 4);
        let sorted = solve_sequence(&ps, &opts(4, 1e-8));
        let mut unsorted_opts = opts(4, 1e-8);
        unsorted_opts.sort = crate::sort::SortMethod::None;
        let unsorted = solve_sequence(&ps, &unsorted_opts);
        assert!(sorted.all_converged() && unsorted.all_converged());
        assert!(
            sorted.filter_mflops() <= unsorted.filter_mflops() * 1.10,
            "sorted {} vs unsorted {}",
            sorted.filter_mflops(),
            unsorted.filter_mflops()
        );
    }

    #[test]
    fn try_adopt_rejects_mismatched_tails() {
        let gen_opts = GenOptions {
            grid: 8,
            ..Default::default()
        };
        let helm = operators::generate(OperatorKind::Helmholtz, gen_opts, 1, 9);
        let pois = operators::generate(OperatorKind::Poisson, gen_opts, 1, 9);
        let small = operators::generate(
            OperatorKind::Helmholtz,
            GenOptions {
                grid: 6,
                ..Default::default()
            },
            1,
            9,
        );
        let o = opts(3, 1e-8);
        let mut backend = crate::eig::chebyshev::NativeFilter::new();
        let mut ws = Workspace::new(1);
        let mut donor = Chain::new();
        donor.solve_next_for(&helm[0].family, &helm[0].matrix, &o, &mut backend, &mut ws);
        let tail = donor.into_tail().expect("warm chain has a tail");
        let n = helm[0].matrix.rows();

        let plain = OpTag::new(
            crate::eig::op::ProblemKind::Standard,
            crate::eig::op::Transform::None,
        );

        // Family mismatch: rejected, nothing adopted.
        let mut c = Chain::new();
        let err = c
            .try_adopt(
                &pois[0].family,
                pois[0].matrix.rows(),
                plain,
                &helm[0].family,
                plain,
                tail.clone(),
            )
            .unwrap_err();
        assert!(err.contains("family mismatch"), "{err}");
        assert!(c.next_is_cold(&o));

        // Dimension mismatch: rejected, nothing adopted.
        let err = c
            .try_adopt(
                &small[0].family,
                small[0].matrix.rows(),
                plain,
                &helm[0].family,
                plain,
                tail.clone(),
            )
            .unwrap_err();
        assert!(err.contains("dimension mismatch"), "{err}");
        assert!(c.next_is_cold(&o));

        // Agreement: adopted, the next solve starts warm.
        c.try_adopt(&helm[0].family, n, plain, &helm[0].family, plain, tail)
            .expect("matching tail adopts");
        assert!(!c.next_is_cold(&o));
    }

    #[test]
    fn try_adopt_rejects_mismatched_operator_modes() {
        // The transform-aware seam checks: a tail solved as a standard
        // problem must not seed a generalized chain (problem-type
        // mismatch), and two shift-inverted runs must agree on σ
        // (shift mismatch). Both reject hard, leaving the chain cold.
        use crate::eig::op::{ProblemKind, Transform};
        let helm = operators::generate(
            OperatorKind::Helmholtz,
            GenOptions {
                grid: 8,
                ..Default::default()
            },
            1,
            9,
        );
        let o = opts(3, 1e-8);
        let mut backend = crate::eig::chebyshev::NativeFilter::new();
        let mut ws = Workspace::new(1);
        let mut donor = Chain::new();
        donor.solve_next_for(&helm[0].family, &helm[0].matrix, &o, &mut backend, &mut ws);
        let tail = donor.into_tail().expect("warm chain has a tail");
        let n = helm[0].matrix.rows();
        let fam = &helm[0].family;
        let plain = OpTag::new(ProblemKind::Standard, Transform::None);
        let gen = OpTag::new(ProblemKind::Generalized, Transform::None);
        let si = |sigma| OpTag::new(ProblemKind::Standard, Transform::ShiftInvert { sigma });

        // Standard tail into a generalized chain: problem-type mismatch.
        let mut c = Chain::new();
        let err = c
            .try_adopt(fam, n, gen, fam, plain, tail.clone())
            .unwrap_err();
        assert!(err.contains("problem-type mismatch"), "{err}");
        assert!(err.contains("standard") && err.contains("generalized"), "{err}");
        assert!(c.next_is_cold(&o));

        // Plain tail into a shift-inverted chain: shift mismatch.
        let err = c
            .try_adopt(fam, n, si(1.5), fam, plain, tail.clone())
            .unwrap_err();
        assert!(err.contains("shift mismatch"), "{err}");
        assert!(c.next_is_cold(&o));

        // Two shift-inverted runs with different σ: shift mismatch too.
        let err = c
            .try_adopt(fam, n, si(1.5), fam, si(2.5), tail.clone())
            .unwrap_err();
        assert!(err.contains("shift mismatch"), "{err}");
        assert!(err.contains("shift_invert:2.5") && err.contains("shift_invert:1.5"), "{err}");
        assert!(c.next_is_cold(&o));

        // Same σ on both sides agrees.
        c.try_adopt(fam, n, si(1.5), fam, si(1.5), tail)
            .expect("matching modes adopt");
        assert!(!c.next_is_cold(&o));
    }

    #[test]
    fn deflate_chain_converges_and_carries_a_bounded_recycle_space() {
        let chain = operators::helmholtz::generate_perturbed_chain(
            GenOptions {
                grid: 10,
                ..Default::default()
            },
            5,
            0.05,
            3,
        );
        let mut o = opts(5, 1e-8);
        o.sort = crate::sort::SortMethod::None;
        o.chfsi.recycling = Recycling::Deflate;
        let seq = solve_sequence(&chain, &o);
        assert!(seq.all_converged());
        for (pos, &pid) in seq.order.iter().enumerate() {
            let want = sym_eig(&chain[pid].matrix.to_dense());
            for (got, wv) in seq.results[pos].values.iter().zip(&want.values[..5]) {
                assert!(
                    (got - wv).abs() / wv.abs().max(1.0) < 1e-6,
                    "problem {pid}: {got} vs {wv}"
                );
            }
        }
        // Every warm solve saw a carried recycle space, and the space
        // stayed under the auto cap (twice the iterate-block width).
        let block = o.chfsi.block_width(chain[0].matrix.rows());
        assert!(seq.results[1..].iter().all(|r| r.stats.recycle_dim > 0));
        assert!(seq.results.iter().all(|r| r.stats.recycle_dim <= 2 * block));
        assert!(seq.recycle_matvecs() <= seq.total_matvecs());
    }

    #[test]
    fn deflate_seed_locks_along_a_tight_chain() {
        // Identical matrices down the chain: from the second solve on,
        // every inherited pair prices at its converged residual and
        // seed-locks, so warm solves cost residual checks, not sweeps.
        let chain = operators::helmholtz::generate_perturbed_chain(
            GenOptions {
                grid: 10,
                ..Default::default()
            },
            4,
            0.0,
            7,
        );
        let mut o = opts(5, 1e-8);
        o.sort = crate::sort::SortMethod::None;
        o.chfsi.recycling = Recycling::Deflate;
        let seq = solve_sequence(&chain, &o);
        assert!(seq.all_converged());
        for r in &seq.results[1..] {
            assert!(
                r.stats.deflated_cols >= 5,
                "tight-chain warm solve deflated only {} columns",
                r.stats.deflated_cols
            );
        }
        assert_eq!(seq.results[0].stats.deflated_cols, 0, "cold solve deflates nothing");

        // Off stays off: no deflation accounting under the default.
        let mut off = o;
        off.chfsi.recycling = Recycling::Off;
        let base = solve_sequence(&chain, &off);
        assert_eq!(base.deflated_cols(), 0);
        assert_eq!(base.recycle_matvecs(), 0);
        assert!(base.results.iter().all(|r| r.stats.recycle_dim == 0));
    }

    #[test]
    fn stats_accessors_are_consistent() {
        let ps = dataset(3, 5);
        let seq = solve_sequence(&ps, &opts(4, 1e-8));
        assert!(seq.avg_secs() > 0.0);
        assert!(seq.avg_iterations() >= 1.0);
        assert!(seq.total_mflops() >= seq.filter_mflops());
        assert_eq!(seq.order.len(), 3);
    }
}
