//! Minimal JSON implementation (value model, parser, writer).
//!
//! The offline build environment only vendors the `xla` crate's dependency
//! closure, so `serde_json` is unavailable; dataset manifests and run
//! configs need only this small, well-tested subset of JSON:
//! objects, arrays, strings (with `\uXXXX` escapes), numbers (f64),
//! booleans and null.

use std::collections::BTreeMap;
use std::fmt;

/// Maximum container nesting accepted by both this tree parser and the
/// streaming pull parser ([`crate::store::pull`]). Deeper input is a
/// hard [`ParseError`] — never a stack overflow. Generously above
/// anything a manifest or config produces (which nest < 10 deep).
pub const MAX_DEPTH: usize = 128;

/// A JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (stable key order) — important for reproducible
/// manifests and golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Get an object field.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64, if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As usize, if numeric and integral-ish.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x.round() as usize)
    }

    /// As &str, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// As bool, if boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice, if an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        write_value(self, &mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_pretty(self, &mut s, 0);
        s.push('\n');
        s
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::Num(x)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Self {
        Value::Num(x as f64)
    }
}
impl From<u64> for Value {
    fn from(x: u64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<bool> for Value {
    fn from(x: bool) -> Self {
        Value::Bool(x)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Self {
        Value::Str(x.to_string())
    }
}
impl From<String> for Value {
    fn from(x: String) -> Self {
        Value::Str(x)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(xs: Vec<T>) -> Self {
        Value::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// Escape and quote a string. Shared with the streaming emitter
/// ([`crate::store::emit`]) so both serializers produce identical bytes.
pub(crate) fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Format a number the way every manifest writer must: integral values
/// below 2⁵³ print as integers, everything else via f64 `Display`.
/// Shared with the streaming emitter for byte-identical output.
pub(crate) fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        // JSON has no Inf/NaN; fail loudly rather than emit invalid JSON.
        panic!("non-finite number cannot be serialized to JSON: {x}");
    }
    if x == x.trunc() && x.abs() < 9e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(x) => write_num(*x, out),
        Value::Str(s) => write_escaped(s, out),
        Value::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

fn write_pretty(v: &Value, out: &mut String, indent: usize) {
    let pad = "  ".repeat(indent);
    let pad1 = "  ".repeat(indent + 1);
    match v {
        Value::Arr(xs) if !xs.is_empty() => {
            out.push_str("[\n");
            for (i, x) in xs.iter().enumerate() {
                out.push_str(&pad1);
                write_pretty(x, out, indent + 1);
                if i + 1 < xs.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&pad);
            out.push(']');
        }
        Value::Obj(m) if !m.is_empty() => {
            out.push_str("{\n");
            for (i, (k, x)) in m.iter().enumerate() {
                out.push_str(&pad1);
                write_escaped(k, out);
                out.push_str(": ");
                write_pretty(x, out, indent + 1);
                if i + 1 < m.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&pad);
            out.push('}');
        }
        other => write_value(other, out),
    }
}

/// JSON parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    /// Byte offset where the error occurred.
    pub at: usize,
    /// Human-readable message.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.at, self.msg)
    }
}
impl std::error::Error for ParseError {}

/// Parse a JSON document. Trailing whitespace is permitted; trailing
/// garbage is an error.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let bytes = input.as_bytes();
    let mut p = Parser {
        bytes,
        pos: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting; bounded by [`MAX_DEPTH`] so malicious
    /// or corrupt input errors out instead of overflowing the stack
    /// (this parser recurses once per level).
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            at: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not needed for
                            // manifests); map them to the replacement char.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn enter(&mut self) -> Result<(), ParseError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        self.depth += 1;
        Ok(())
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Value::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar_values() {
        for src in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, back, "roundtrip {src}");
        }
    }

    #[test]
    fn parse_nested_document() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": true}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Value::Bool(true)));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Value::Num(42.0).to_string_compact(), "42");
        assert_eq!(Value::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn deterministic_object_order() {
        let v = Value::obj(vec![("z", 1usize.into()), ("a", 2usize.into())]);
        assert_eq!(v.to_string_compact(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Value::obj(vec![
            ("xs", vec![1usize, 2, 3].into()),
            ("name", "scsf".into()),
        ]);
        let s = v.to_string_pretty();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn deeply_nested_input_is_an_error_not_a_stack_overflow() {
        // 100k levels would blow the stack if the limit were missing.
        let deep_arr = "[".repeat(100_000);
        let err = parse(&deep_arr).unwrap_err();
        assert!(err.msg.contains("nesting"), "{err}");
        let deep_obj = "{\"k\":".repeat(100_000);
        let err = parse(&deep_obj).unwrap_err();
        assert!(err.msg.contains("nesting"), "{err}");
        // The boundary: MAX_DEPTH parses, MAX_DEPTH + 1 does not.
        let ok = format!("{}{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(parse(&ok).is_ok());
        let over = format!(
            "{}{}",
            "[".repeat(MAX_DEPTH + 1),
            "]".repeat(MAX_DEPTH + 1)
        );
        assert!(parse(&over).is_err());
    }

    #[test]
    fn escaped_control_chars_roundtrip() {
        let v = Value::Str("tab\there\u{1}".to_string());
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }
}
