//! Sparse matrices in CSR form.
//!
//! The discretized PDE operators of the paper are 2-D finite-difference /
//! finite-element stencils: 5–13 non-zeros per row. The Chebyshev filter —
//! more than 70 % of SCSF's flops (paper Table 11) — is a chain of sparse
//! matrix × tall-dense-block products, so [`CsrMatrix::spmm`] is the
//! hottest kernel in the library (see EXPERIMENTS.md §Perf).

pub mod csr;
pub mod ldlt;
pub mod sell;

pub use csr::{CooBuilder, CsrMatrix, CsrMatrixF32};
pub use ldlt::LdltFactor;
pub use sell::{SellMatrix, SellMatrixF32, SELL_CHUNK};
