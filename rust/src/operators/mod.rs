//! The paper's four operator-eigenvalue dataset families (§D.2), plus the
//! FEM parameterization of Table 19. Each family turns GRF-sampled (or
//! uniformly sampled) coefficients into a sparse symmetric matrix by FDM
//! central differences (or Q1 FEM), i.e. steps 1–3 of the paper's Figure 1.
//!
//! ## Sign conventions
//!
//! All experiments compute the smallest-`|λ|` eigenpairs of self-adjoint
//! operators. We fix signs so every assembled matrix is symmetric
//! positive-(semi)definite — e.g. the generalized Poisson operator is
//! assembled as `−∇·(K∇)` — which makes *smallest-algebraic* coincide
//! with *smallest-in-modulus*. This matches the paper's setting (its
//! baselines are all "smallest" Hermitian solvers) and is documented in
//! DESIGN.md §Substitutions.

pub mod elliptic;
pub mod fem;
pub mod helmholtz;
pub mod poisson;
pub mod vibration;

use crate::grf::GrfParams;
use crate::rng::Xoshiro256pp;
use crate::sparse::CsrMatrix;

/// Which dataset family a problem belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OperatorKind {
    /// Generalized Poisson `−∇·(K∇u) = λu` (paper precision 1e-12).
    Poisson,
    /// Constant-coefficient second-order elliptic operator (1e-10).
    Elliptic,
    /// Helmholtz `−∇·(p∇u) + k²u = λu` (1e-8).
    Helmholtz,
    /// Fourth-order plate vibration `∇²(D∇²u) = λρu` (1e-8).
    Vibration,
    /// Helmholtz discretized with Q1 FEM + lumped mass (Table 19).
    HelmholtzFem,
}

impl OperatorKind {
    /// Paper's per-dataset solve tolerance (relative residual).
    pub fn default_tol(self) -> f64 {
        match self {
            OperatorKind::Poisson => 1e-12,
            OperatorKind::Elliptic => 1e-10,
            OperatorKind::Helmholtz | OperatorKind::HelmholtzFem => 1e-8,
            OperatorKind::Vibration => 1e-8,
        }
    }

    /// Stable name used in manifests and CLI flags.
    pub fn name(self) -> &'static str {
        match self {
            OperatorKind::Poisson => "poisson",
            OperatorKind::Elliptic => "elliptic",
            OperatorKind::Helmholtz => "helmholtz",
            OperatorKind::Vibration => "vibration",
            OperatorKind::HelmholtzFem => "helmholtz_fem",
        }
    }

    /// Parse a name produced by [`OperatorKind::name`].
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "poisson" => OperatorKind::Poisson,
            "elliptic" => OperatorKind::Elliptic,
            "helmholtz" => OperatorKind::Helmholtz,
            "vibration" => OperatorKind::Vibration,
            "helmholtz_fem" => OperatorKind::HelmholtzFem,
            _ => return None,
        })
    }
}

/// The sorting key of a problem: the parameter data the truncated-FFT /
/// greedy sorting compares (paper Algorithm 2's `P^{(i)}`).
#[derive(Debug, Clone, PartialEq)]
pub enum SortKey {
    /// One or more `p × p` coefficient fields (row-major).
    Fields(Vec<Field>),
    /// A short coefficient vector (the elliptic family's 6 constants);
    /// FFT truncation is a no-op for these.
    Coeffs(Vec<f64>),
}

/// A square coefficient field.
#[derive(Debug, Clone, PartialEq)]
pub struct Field {
    /// Side length `p` of the field.
    pub p: usize,
    /// Row-major `p × p` samples.
    pub data: Vec<f64>,
}

impl SortKey {
    /// Squared Euclidean distance between two keys of the same shape —
    /// the "exact" (untruncated) distance the greedy sort uses.
    pub fn dist2(&self, other: &SortKey) -> f64 {
        match (self, other) {
            (SortKey::Fields(a), SortKey::Fields(b)) => {
                assert_eq!(a.len(), b.len(), "sort-key field count mismatch");
                a.iter()
                    .zip(b)
                    .map(|(fa, fb)| {
                        assert_eq!(fa.p, fb.p);
                        fa.data
                            .iter()
                            .zip(&fb.data)
                            .map(|(x, y)| (x - y) * (x - y))
                            .sum::<f64>()
                    })
                    .sum()
            }
            (SortKey::Coeffs(a), SortKey::Coeffs(b)) => {
                assert_eq!(a.len(), b.len());
                a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
            }
            _ => panic!("sort-key kind mismatch"),
        }
    }
}

/// One eigenvalue problem of a dataset: the assembled matrix plus the
/// parameter data it came from.
#[derive(Debug, Clone)]
pub struct Problem {
    /// Stable index within the generated dataset (pre-sorting order).
    pub id: usize,
    /// Which family the problem belongs to.
    pub kind: OperatorKind,
    /// The assembled symmetric sparse matrix.
    pub matrix: CsrMatrix,
    /// Parameter data used by the sorting algorithms.
    pub sort_key: SortKey,
}

impl Problem {
    /// Matrix dimension `n`.
    pub fn n(&self) -> usize {
        self.matrix.rows()
    }
}

/// Generation knobs shared by all families.
#[derive(Debug, Clone, Copy)]
pub struct GenOptions {
    /// Interior grid side `g` (matrix dimension is `g²`).
    pub grid: usize,
    /// GRF smoothness/length-scale for coefficient fields.
    pub grf: GrfParams,
}

impl Default for GenOptions {
    fn default() -> Self {
        Self {
            grid: 32,
            grf: GrfParams::default(),
        }
    }
}

/// Generate `count` problems of the given family (steps 1–3 of Figure 1).
/// Deterministic in `seed`.
pub fn generate(
    kind: OperatorKind,
    opts: GenOptions,
    count: usize,
    seed: u64,
) -> Vec<Problem> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..count)
        .map(|id| {
            let mut prng = rng.fork();
            generate_one(kind, opts, id, &mut prng)
        })
        .collect()
}

/// Generate a single problem from an explicit per-problem RNG stream.
pub fn generate_one(
    kind: OperatorKind,
    opts: GenOptions,
    id: usize,
    rng: &mut Xoshiro256pp,
) -> Problem {
    match kind {
        OperatorKind::Poisson => poisson::generate(opts, id, rng),
        OperatorKind::Elliptic => elliptic::generate(opts, id, rng),
        OperatorKind::Helmholtz => helmholtz::generate(opts, id, rng),
        OperatorKind::Vibration => vibration::generate(opts, id, rng),
        OperatorKind::HelmholtzFem => fem::generate(opts, id, rng),
    }
}

/// Map interior grid point `(i, j)` (0-based) to the row-major unknown
/// index on a `g × g` interior grid.
#[inline]
pub(crate) fn idx(g: usize, i: usize, j: usize) -> usize {
    i * g + j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_name_roundtrip() {
        for k in [
            OperatorKind::Poisson,
            OperatorKind::Elliptic,
            OperatorKind::Helmholtz,
            OperatorKind::Vibration,
            OperatorKind::HelmholtzFem,
        ] {
            assert_eq!(OperatorKind::parse(k.name()), Some(k));
        }
        assert_eq!(OperatorKind::parse("nope"), None);
    }

    #[test]
    fn all_families_assemble_symmetric_psd_matrices() {
        let opts = GenOptions {
            grid: 8,
            ..Default::default()
        };
        for kind in [
            OperatorKind::Poisson,
            OperatorKind::Elliptic,
            OperatorKind::Helmholtz,
            OperatorKind::Vibration,
            OperatorKind::HelmholtzFem,
        ] {
            let ps = generate(kind, opts, 2, 42);
            assert_eq!(ps.len(), 2);
            for p in &ps {
                assert_eq!(p.n(), 64, "{kind:?}");
                assert!(
                    p.matrix.asymmetry() < 1e-10,
                    "{kind:?} asymmetry {}",
                    p.matrix.asymmetry()
                );
                // PSD check via full dense spectrum at this small size.
                let eig = crate::linalg::symeig::sym_eig(&p.matrix.to_dense());
                assert!(
                    eig.values[0] > -1e-8,
                    "{kind:?} has negative eigenvalue {}",
                    eig.values[0]
                );
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let opts = GenOptions {
            grid: 6,
            ..Default::default()
        };
        let a = generate(OperatorKind::Helmholtz, opts, 3, 7);
        let b = generate(OperatorKind::Helmholtz, opts, 3, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.matrix, y.matrix);
            assert_eq!(x.sort_key, y.sort_key);
        }
    }

    #[test]
    fn problems_within_a_dataset_differ() {
        let opts = GenOptions {
            grid: 6,
            ..Default::default()
        };
        let ps = generate(OperatorKind::Poisson, opts, 2, 1);
        assert_ne!(ps[0].matrix, ps[1].matrix);
    }

    #[test]
    fn sort_key_distance_properties() {
        let a = SortKey::Coeffs(vec![1.0, 2.0]);
        let b = SortKey::Coeffs(vec![1.0, 4.0]);
        assert_eq!(a.dist2(&a), 0.0);
        assert_eq!(a.dist2(&b), 4.0);
        assert_eq!(b.dist2(&a), 4.0);
    }
}
