//! QR factorizations and block orthonormalization.
//!
//! ChFSI (paper Algorithm 3, line 4) re-orthonormalizes the filtered
//! block every iteration. The default path is CholeskyQR2 (GEMM-shaped,
//! ~3× faster than Householder on tall blocks — EXPERIMENTS.md §Perf)
//! with an automatic fall back to Householder QR when the filter has
//! made the block too ill-conditioned for the Gram-matrix approach.

use super::dense::Mat;
use super::flops;

/// Thin QR of a tall matrix `A (n × k, n ≥ k)` via Householder reflectors.
///
/// Returns `Q (n × k)` with orthonormal columns such that `A = Q R`
/// (`R` is discarded — the solvers only need the orthonormal basis).
/// Columns whose remaining norm underflows (exact rank deficiency) are
/// replaced by fresh orthonormal directions, so `Q` always has full
/// column rank.
pub fn householder_qr(a: &Mat) -> Mat {
    let (n, k) = (a.rows(), a.cols());
    assert!(n >= k, "householder_qr expects a tall matrix");
    // Factor: store Householder vectors in the lower trapezoid of `w`.
    let mut w = a.clone();
    let mut betas = vec![0.0f64; k];
    flops::add((4 * n * k * k) as u64);
    for j in 0..k {
        // Norm of column j below (and including) the diagonal.
        let mut sigma = 0.0;
        for i in j..n {
            sigma += w[(i, j)] * w[(i, j)];
        }
        let norm = sigma.sqrt();
        if norm < 1e-300 {
            betas[j] = 0.0; // exactly zero column; handled after Q build
            continue;
        }
        let alpha = if w[(j, j)] >= 0.0 { -norm } else { norm };
        let v0 = w[(j, j)] - alpha;
        // v = [v0, a_{j+1..n,j}] ; beta = 2 / vᵀv.
        let vtv = sigma - w[(j, j)] * w[(j, j)] + v0 * v0;
        let beta = if vtv > 0.0 { 2.0 / vtv } else { 0.0 };
        w[(j, j)] = v0;
        betas[j] = beta;
        // Apply H = I − beta v vᵀ to the trailing columns.
        for c in (j + 1)..k {
            let mut s = 0.0;
            for i in j..n {
                s += w[(i, j)] * w[(i, c)];
            }
            s *= beta;
            for i in j..n {
                let vij = w[(i, j)];
                w[(i, c)] -= s * vij;
            }
        }
        // The diagonal of R would be alpha; not stored.
        let _ = alpha;
    }
    // Accumulate Q = H_0 … H_{k-1} · [e_1 … e_k].
    let mut q = Mat::zeros(n, k);
    for j in 0..k {
        q[(j, j)] = 1.0;
    }
    flops::add((4 * n * k * k) as u64);
    for j in (0..k).rev() {
        if betas[j] == 0.0 {
            continue;
        }
        for c in 0..k {
            let mut s = 0.0;
            for i in j..n {
                s += w[(i, j)] * q[(i, c)];
            }
            s *= betas[j];
            for i in j..n {
                let vij = w[(i, j)];
                q[(i, c)] -= s * vij;
            }
        }
    }
    // Repair exactly-deficient columns (rare; e.g. duplicated input
    // vectors): re-orthogonalize the affected column of the identity seed.
    for j in 0..k {
        if betas[j] == 0.0 {
            regenerate_column(&mut q, j);
        }
    }
    q
}

/// Replace column `j` of `q` with a unit vector orthogonal to all other
/// columns (deterministic: tries coordinate directions in order).
fn regenerate_column(q: &mut Mat, j: usize) {
    let (n, k) = (q.rows(), q.cols());
    for seed in 0..n {
        let mut v = vec![0.0; n];
        v[seed] = 1.0;
        for c in 0..k {
            if c == j {
                continue;
            }
            let mut s = 0.0;
            for i in 0..n {
                s += q[(i, c)] * v[i];
            }
            for i in 0..n {
                v[i] -= s * q[(i, c)];
            }
        }
        let nrm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if nrm > 1e-8 {
            for i in 0..n {
                q[(i, j)] = v[i] / nrm;
            }
            return;
        }
    }
    panic!("could not regenerate an orthogonal column (k > n?)");
}

/// Cholesky factorization `G = L·Lᵀ` of a symmetric positive-definite
/// matrix. Returns `None` when a pivot degenerates (not SPD / severe
/// rank deficiency) — callers fall back to Householder.
pub fn cholesky(g: &Mat) -> Option<Mat> {
    let k = g.rows();
    assert_eq!(k, g.cols());
    let mut l = Mat::zeros(k, k);
    flops::add((k * k * k) as u64 / 3);
    let scale = (0..k).map(|i| g[(i, i)].abs()).fold(0.0f64, f64::max);
    for j in 0..k {
        let mut d = g[(j, j)];
        for p in 0..j {
            d -= l[(j, p)] * l[(j, p)];
        }
        if !(d > 1e-14 * scale.max(1e-300)) {
            return None;
        }
        let dj = d.sqrt();
        l[(j, j)] = dj;
        for i in (j + 1)..k {
            let mut s = g[(i, j)];
            for p in 0..j {
                s -= l[(i, p)] * l[(j, p)];
            }
            l[(i, j)] = s / dj;
        }
    }
    Some(l)
}

/// In-place right-solve `Q ← Q · L⁻ᵀ` with `L` lower-triangular — the
/// normalization step of CholeskyQR. Row-major `Q` makes each row an
/// independent forward substitution (unit-stride, cache-friendly).
fn trsm_right_ltrans(q: &mut Mat, l: &Mat) {
    let k = l.rows();
    assert_eq!(q.cols(), k);
    flops::add((q.rows() * k * k) as u64);
    for r in 0..q.rows() {
        let row = q.row_mut(r);
        // Solve x Lᵀ = row  ⇔  L x' = row' columnwise: forward order.
        for j in 0..k {
            let mut s = row[j];
            for p in 0..j {
                s -= l[(j, p)] * row[p];
            }
            row[j] = s / l[(j, j)];
        }
    }
}

/// CholeskyQR2: two rounds of `Q ← Q·chol(QᵀQ)⁻ᵀ`. GEMM-shaped and
/// 2–3× faster than Householder on tall blocks; numerically fine when
/// the first Gram matrix is not catastrophically conditioned, which the
/// `cholesky` pivot check detects (→ `None`, caller falls back).
pub fn chol_qr2(a: &Mat) -> Option<Mat> {
    let mut q = a.clone();
    for _round in 0..2 {
        let g = q.t_matmul(&q);
        let l = cholesky(&g)?;
        trsm_right_ltrans(&mut q, &l);
    }
    Some(q)
}

/// Orthonormalize `block` against an existing orthonormal basis `locked`
/// and then internally: the `QR = [V~ | V0]` step of Algorithm 3 with the
/// locked pairs kept fixed.
///
/// Two passes of projection (DGKS criterion unconditionally applied
/// twice) followed by CholeskyQR2 of the remainder, with a Householder
/// fallback when the filtered block is too ill-conditioned for the Gram
/// approach (EXPERIMENTS.md §Perf documents the speedup).
pub fn ortho_against(locked: Option<&Mat>, block: &Mat) -> Mat {
    let mut b = block.clone();
    let mut gram = Mat::zeros(0, 0);
    let mut corr = Mat::zeros(0, 0);
    ortho_against_inplace(locked, &mut b, &mut gram, &mut corr);
    b
}

/// Buffer-reusing variant of [`ortho_against`]: `block` is
/// orthonormalized in place using caller-provided Gram (`gram`) and
/// correction (`corr`) scratch, so the per-iteration QR of the ChFSI
/// loop costs no heap traffic once the workspace has grown to size.
/// The arithmetic is identical to [`ortho_against`] (same projection,
/// normalization, CholeskyQR2 rounds and Householder fallback on the
/// same input), so results are bit-for-bit equal.
pub fn ortho_against_inplace(
    locked: Option<&Mat>,
    block: &mut Mat,
    gram: &mut Mat,
    corr: &mut Mat,
) {
    ortho_against_cols_inplace(locked.map(|u| (u, u.cols())), block, gram, corr)
}

/// [`ortho_against_inplace`] against only the first `count` columns of
/// the locked matrix — the entry point for ChFSI's preallocated
/// locked-basis buffer, whose populated prefix grows in place as pairs
/// lock ([`crate::eig::solver::Workspace`]). With `count ==
/// locked.cols()` the arithmetic (and therefore the result) is
/// bit-for-bit [`ortho_against_inplace`]'s.
pub fn ortho_against_cols_inplace(
    locked: Option<(&Mat, usize)>,
    block: &mut Mat,
    gram: &mut Mat,
    corr: &mut Mat,
) {
    if let Some((u, count)) = locked {
        assert_eq!(u.rows(), block.rows());
        assert!(count <= u.cols());
        for _pass in 0..2 {
            // B ← B − U[:, :count] (U[:, :count]ᵀ B)
            u.t_matmul_ncols_into(count, block, gram);
            u.matmul_ncols_into(count, gram, corr);
            block.axpy(-1.0, corr);
        }
    }
    // The Chebyshev filter scales columns by up to ρ(λ₁) ≫ 1; normalize
    // columns first so the Gram matrix is well-scaled.
    for j in 0..block.cols() {
        let nrm = block.col_norm(j);
        if nrm > 1e-300 {
            let inv = 1.0 / nrm;
            for i in 0..block.rows() {
                block[(i, j)] *= inv;
            }
        }
    }
    // CholeskyQR2 in place; `corr` snapshots the normalized input so the
    // rare Householder fallback sees exactly what [`ortho_against`]'s
    // non-mutating `chol_qr2` call would have seen.
    corr.copy_from(block);
    let mut ok = true;
    for _round in 0..2 {
        {
            let q: &Mat = block;
            q.t_matmul_into(q, gram);
        }
        match cholesky(gram) {
            Some(l) => trsm_right_ltrans(block, &l),
            None => {
                ok = false;
                break;
            }
        }
    }
    if !ok {
        let q = householder_qr(corr);
        block.copy_from(&q);
    }
}

/// Orthonormality defect `‖QᵀQ − I‖_max` — used by tests and the
/// validation stage of the pipeline.
pub fn ortho_defect(q: &Mat) -> f64 {
    let g = q.t_matmul(q);
    let k = g.rows();
    let mut worst: f64 = 0.0;
    for i in 0..k {
        for j in 0..k {
            let target = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((g[(i, j)] - target).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;


    #[test]
    fn cholesky_of_identityish() {
        let g = Mat::from_vec(2, 2, vec![4.0, 2.0, 2.0, 5.0]);
        let l = cholesky(&g).unwrap();
        // L Lᵀ == G
        let lt = l.transpose();
        let back = l.matmul(&lt);
        assert!(back.max_abs_diff(&g) < 1e-12);
        // Not SPD -> None
        let bad = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        assert!(cholesky(&bad).is_none());
    }

    #[test]
    fn chol_qr2_matches_householder_span() {
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let a = Mat::randn(60, 7, &mut rng);
        let q = chol_qr2(&a).unwrap();
        assert!(ortho_defect(&q) < 1e-12);
        // Same span as the input.
        let coeff = q.t_matmul(&a);
        let back = q.matmul(&coeff);
        assert!(back.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn chol_qr2_fails_gracefully_on_rank_deficiency() {
        let mut rng = Xoshiro256pp::seed_from_u64(12);
        let a = Mat::randn(30, 3, &mut rng);
        let dup = a.hcat(&a.cols_range(0, 1)); // duplicated column
        assert!(chol_qr2(&dup).is_none());
        // ortho_against still succeeds via the Householder fallback.
        let q = ortho_against(None, &dup);
        assert!(ortho_defect(&q) < 1e-9);
    }

    #[test]
    fn qr_produces_orthonormal_basis() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let a = Mat::randn(50, 8, &mut rng);
        let q = householder_qr(&a);
        assert_eq!((q.rows(), q.cols()), (50, 8));
        assert!(ortho_defect(&q) < 1e-12, "defect {}", ortho_defect(&q));
    }

    #[test]
    fn qr_preserves_column_span() {
        // span(Q) == span(A): projecting A onto Q reproduces A.
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let a = Mat::randn(30, 5, &mut rng);
        let q = householder_qr(&a);
        let coeff = q.t_matmul(&a);
        let back = q.matmul(&coeff);
        assert!(back.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn qr_handles_rank_deficiency() {
        // Two identical columns: Q must still be orthonormal.
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let a = Mat::randn(20, 3, &mut rng);
        let mut bad = a.hcat(&a.cols_range(0, 1));
        // also a zero column
        bad = bad.hcat(&Mat::zeros(20, 1));
        let q = householder_qr(&bad);
        assert!(ortho_defect(&q) < 1e-10, "defect {}", ortho_defect(&q));
    }

    #[test]
    fn ortho_against_locks_existing_basis() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let u = householder_qr(&Mat::randn(40, 4, &mut rng));
        let b = Mat::randn(40, 6, &mut rng);
        let q = ortho_against(Some(&u), &b);
        assert!(ortho_defect(&q) < 1e-12);
        // Q ⟂ U:
        let cross = u.t_matmul(&q);
        let max = cross.data().iter().fold(0.0f64, |m, x| m.max(x.abs()));
        assert!(max < 1e-12, "cross {max}");
    }

    #[test]
    fn ortho_against_none_is_plain_qr() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let b = Mat::randn(25, 5, &mut rng);
        let q = ortho_against(None, &b);
        assert!(ortho_defect(&q) < 1e-12);
    }

    #[test]
    fn ortho_against_inplace_matches_alloc_version() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let u = householder_qr(&Mat::randn(32, 3, &mut rng));
        for locked in [None, Some(&u)] {
            let b = Mat::randn(32, 5, &mut rng);
            let want = ortho_against(locked, &b);
            let mut got = b.clone();
            let mut gram = Mat::zeros(0, 0);
            let mut corr = Mat::zeros(0, 0);
            ortho_against_inplace(locked, &mut got, &mut gram, &mut corr);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn cols_limited_ortho_matches_sliced_locked_basis() {
        // The preallocated locked-buffer path: projecting against the
        // first `c` columns of a wide buffer must be bit-for-bit equal
        // to projecting against a c-column matrix holding those
        // columns (the historical hcat-built locked basis).
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let wide = householder_qr(&Mat::randn(28, 6, &mut rng));
        for c in 0..=6usize {
            let b = Mat::randn(28, 4, &mut rng);
            let mut want = b.clone();
            let (mut g1, mut c1) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
            let sliced = wide.cols_range(0, c);
            ortho_against_inplace((c > 0).then_some(&sliced), &mut want, &mut g1, &mut c1);
            let mut got = b.clone();
            let (mut g2, mut c2) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
            ortho_against_cols_inplace(
                (c > 0).then_some((&wide, c)),
                &mut got,
                &mut g2,
                &mut c2,
            );
            assert_eq!(got, want, "count = {c}");
        }
    }

    #[test]
    fn ortho_against_inplace_survives_rank_deficiency() {
        // Duplicated columns force the Householder fallback path.
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let a = Mat::randn(30, 3, &mut rng);
        let dup = a.hcat(&a.cols_range(0, 1));
        let want = ortho_against(None, &dup);
        let mut got = dup.clone();
        let (mut gram, mut corr) = (Mat::zeros(0, 0), Mat::zeros(0, 0));
        ortho_against_inplace(None, &mut got, &mut gram, &mut corr);
        assert_eq!(got, want);
        assert!(ortho_defect(&got) < 1e-9);
    }

    #[test]
    fn square_qr_is_orthogonal_matrix() {
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let a = Mat::randn(12, 12, &mut rng);
        let q = householder_qr(&a);
        assert!(ortho_defect(&q) < 1e-12);
    }
}
