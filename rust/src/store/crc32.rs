//! CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`) — the
//! checksum sealing every chunked-manifest frame (see
//! [`super::chunk`]). Implemented in-tree because the offline build has
//! no registry access; table-driven, one byte per step, which is far
//! faster than the frames it guards need.

/// The 256-entry lookup table, computed at compile time.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 {
                (crc >> 1) ^ 0xEDB8_8320
            } else {
                crc >> 1
            };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// Incremental CRC-32 state (for checksumming a frame streamed in
/// pieces). [`crc32`] is the one-shot convenience.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    /// Fresh checksum state.
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    /// The final checksum value.
    pub fn finish(&self) -> u32 {
        !self.state
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical check value of CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        for split in 0..data.len() {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finish(), crc32(data), "split at {split}");
        }
    }

    #[test]
    fn detects_single_bit_flips() {
        let data = b"{\"frame\":\"chunk\",\"records\":[1,2,3]}\n";
        let want = crc32(data);
        let mut copy = data.to_vec();
        for i in 0..copy.len() {
            copy[i] ^= 0x10;
            assert_ne!(crc32(&copy), want, "flip at byte {i} undetected");
            copy[i] ^= 0x10;
        }
    }
}
