//! Bench: paper Tables 18–20 — discontinuous (mixed) datasets, FEM
//! parameterization, and high-frequency energy ratios.
use scsf::bench_support::{tables, Scale};

fn main() {
    let scale = Scale::quick();
    tables::table18(&scale, &[(4, 4), (3, 4), (2, 4), (1, 4), (0, 4)]).print();
    println!();
    tables::table19(&scale).print();
    println!();
    tables::table20(&scale).print();
}
