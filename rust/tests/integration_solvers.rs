//! Cross-solver integration: every solver × every operator family must
//! agree with the dense reference and with each other.

use scsf::eig::{EigOptions, SolverKind};
use scsf::linalg::symeig::sym_eig;
use scsf::operators::{self, GenOptions, OperatorKind};

fn opts(l: usize, tol: f64) -> EigOptions {
    EigOptions {
        n_eigs: l,
        tol,
        max_iters: 800,
        seed: 0,
    }
}

const SOLVERS: [SolverKind; 5] = [
    SolverKind::Eigsh,
    SolverKind::Lobpcg,
    SolverKind::KrylovSchur,
    SolverKind::JacobiDavidson,
    SolverKind::Chfsi,
];

#[test]
fn all_solvers_agree_on_all_families() {
    let gen_opts = GenOptions {
        grid: 10,
        ..Default::default()
    };
    for kind in [
        OperatorKind::Poisson,
        OperatorKind::Elliptic,
        OperatorKind::Helmholtz,
        OperatorKind::Vibration,
        OperatorKind::HelmholtzFem,
    ] {
        let p = &operators::generate(kind, gen_opts, 1, 3)[0];
        let tol = kind.default_tol().max(1e-10);
        let want = sym_eig(&p.matrix.to_dense());
        for solver in SOLVERS {
            let r = solver.solve(&p.matrix, &opts(5, tol), None);
            assert!(r.stats.converged, "{kind:?}/{solver:?} residuals {:?}", r.residuals);
            for (j, (got, w)) in r.values.iter().zip(&want.values[..5]).enumerate() {
                assert!(
                    (got - w).abs() / w.abs().max(1.0) < 1e-6,
                    "{kind:?}/{solver:?} pair {j}: {got} vs {w}"
                );
            }
        }
    }
}

#[test]
fn eigenvectors_satisfy_operator_equation() {
    let gen_opts = GenOptions {
        grid: 12,
        ..Default::default()
    };
    let p = &operators::generate(OperatorKind::Helmholtz, gen_opts, 1, 5)[0];
    for solver in SOLVERS {
        let r = solver.solve(&p.matrix, &opts(6, 1e-9), None);
        let res = scsf::eig::rel_residuals(&p.matrix, &r.values, &r.vectors);
        for (j, rr) in res.iter().enumerate() {
            assert!(*rr < 1e-8, "{solver:?} pair {j}: residual {rr}");
        }
    }
}

#[test]
fn solvers_are_deterministic_given_seed() {
    let gen_opts = GenOptions {
        grid: 9,
        ..Default::default()
    };
    let p = &operators::generate(OperatorKind::Poisson, gen_opts, 1, 7)[0];
    for solver in SOLVERS {
        let a = solver.solve(&p.matrix, &opts(4, 1e-9), None);
        let b = solver.solve(&p.matrix, &opts(4, 1e-9), None);
        assert_eq!(a.values, b.values, "{solver:?} not deterministic");
    }
}

#[test]
fn high_precision_poisson_1e12() {
    // The paper's strictest setting (Poisson at 1e-12).
    let gen_opts = GenOptions {
        grid: 12,
        ..Default::default()
    };
    let p = &operators::generate(OperatorKind::Poisson, gen_opts, 1, 9)[0];
    for solver in [SolverKind::Eigsh, SolverKind::Chfsi] {
        let r = solver.solve(&p.matrix, &opts(8, 1e-12), None);
        assert!(r.stats.converged, "{solver:?}");
        for rr in &r.residuals {
            assert!(*rr <= 1e-11, "{solver:?} residual {rr}");
        }
    }
}

#[test]
fn scsf_sequence_beats_chfsi_in_flops_on_similar_chain() {
    // The paper's core claim at integration level.
    use scsf::eig::chfsi::ChfsiOptions;
    use scsf::eig::scsf::{solve_sequence, ScsfOptions};
    use scsf::sort::SortMethod;
    let chain = operators::helmholtz::generate_perturbed_chain(
        GenOptions {
            grid: 12,
            ..Default::default()
        },
        8,
        0.05,
        11,
    );
    let base = ChfsiOptions::from_eig(&opts(8, 1e-8));
    let scsf_seq = solve_sequence(
        &chain,
        &ScsfOptions {
            chfsi: base,
            sort: SortMethod::TruncatedFft { p0: 8 },
            warm_start: true,
        },
    );
    let chfsi_seq = solve_sequence(
        &chain,
        &ScsfOptions {
            chfsi: base,
            sort: SortMethod::None,
            warm_start: false,
        },
    );
    assert!(scsf_seq.all_converged() && chfsi_seq.all_converged());
    assert!(
        scsf_seq.total_mflops() < chfsi_seq.total_mflops(),
        "scsf {} vs chfsi {}",
        scsf_seq.total_mflops(),
        chfsi_seq.total_mflops()
    );
}
