//! Bench: paper Table 1 / Tables 6–9 / Fig 1 (right) — main solver
//! comparison across all four datasets (quick scale).
use scsf::bench_support::{tables, Scale};

fn main() {
    let scale = Scale::quick();
    for t in tables::table1(&scale) {
        t.print();
        println!();
    }
}
