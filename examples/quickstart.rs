//! Quickstart: generate a small Helmholtz eigenvalue dataset with SCSF
//! and compare against the plain-ChFSI baseline.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use scsf::coordinator::config::{FamilySpec, GenConfig};
use scsf::coordinator::pipeline::{generate_dataset, generate_problems};
use scsf::eig::chfsi::ChfsiOptions;
use scsf::eig::scsf::{solve_sequence, ScsfOptions};
use scsf::eig::EigOptions;
use scsf::sort::SortMethod;

fn main() -> scsf::util::error::Result<()> {
    let cfg = GenConfig {
        families: vec![FamilySpec::new("helmholtz", 8)], // dataset: N=8 Helmholtz problems
        grid: 24,  // matrix dimension 576
        n_eigs: 12, // L smallest eigenpairs per problem
        tol: Some(1e-8),
        seed: 7,
        shards: 1, // this container is single-core; shards>1 helps on multi-core
        ..GenConfig::default()
    };

    // One call generates, sorts, solves, validates, and writes the
    // dataset — the paper's Figure 1 end to end.
    let out = std::env::temp_dir().join("scsf_quickstart");
    let report = generate_dataset(&cfg, &out)?;
    println!("SCSF pipeline: {}", report.summary());

    // Baseline for comparison: same problems, random init per problem
    // (the ChFSI column of the paper's Table 1).
    let problems = generate_problems(&cfg);
    let baseline = solve_sequence(
        &problems,
        &ScsfOptions {
            chfsi: ChfsiOptions::from_eig(&EigOptions {
                n_eigs: cfg.n_eigs,
                tol: cfg.tol.unwrap_or(1e-8),
                max_iters: 500,
                seed: 0,
            }),
            sort: SortMethod::None,
            warm_start: false,
        },
    );
    println!(
        "ChFSI baseline: avg {:.3}s/problem | SCSF: avg {:.3}s/problem | speedup {:.2}x",
        baseline.avg_secs(),
        report.avg_solve_secs,
        baseline.avg_secs() / report.avg_solve_secs
    );
    println!("dataset written to {}", out.display());
    Ok(())
}
