//! Shift-invert spectral-transform bench (ISSUE 9's interior windows).
//!
//! Solves the same interior eigenvalue window of random Helmholtz
//! operators two ways and reports the instrumented cost of each path:
//!
//! * `extremal`     — no transform: an extremal ChFSI solve must
//!   compute *every* pair from the bottom of the spectrum up through
//!   the window (`window_start + window` pairs) and discard the
//!   leading `window_start`
//! * `shift_invert` — `transform: shift_invert:σ` with σ in the gap
//!   just below the window: the filter runs on `−(A − σI)⁻¹` and
//!   resolves exactly the `window` wanted pairs, paying one sparse
//!   LDLᵀ factorization up front and two triangular sweeps per
//!   operator application
//!
//! σ is derived from the extremal arm's own output (midpoint of the
//! spectral gap below the window), so the bench needs no dense oracle
//! and both arms target provably identical eigenvalues. Both arms must
//! converge with all residuals ≤ tol and agree on the window values —
//! the transform trades work, never accuracy. Emits
//! `BENCH_transform.json` (working directory) with per-problem matvec
//! profiles, trisolve counts, and factorization time; the repo root
//! carries the committed schema seed. The run asserts the headline:
//! shift-invert reaches the window in ≤ 60 % of the extremal arm's
//! operator applications.

use scsf::eig::chfsi::{self, ChfsiOptions};
use scsf::eig::op::Transform;
use scsf::eig::{EigOptions, EigResult};
use scsf::operators::{self, GenOptions, OperatorKind};
use scsf::util::json::Value;

const GRID: usize = 20;
const N_PROBLEMS: usize = 4;
const WINDOW_START: usize = 12;
const WINDOW: usize = 4;
const TOL: f64 = 1e-8;
const SEED: u64 = 61;

fn solve(a: &scsf::sparse::CsrMatrix, n_eigs: usize, transform: Transform) -> EigResult {
    let mut opts = ChfsiOptions::from_eig(&EigOptions {
        n_eigs,
        tol: TOL,
        max_iters: 600,
        seed: 0,
    });
    opts.transform = transform;
    let r = chfsi::solve(a, &opts, None);
    assert!(r.stats.converged, "arm failed to converge: {:?}", r.residuals);
    for res in &r.residuals {
        assert!(*res <= TOL, "residual {res} > {TOL}");
    }
    r
}

fn arm_record(results: &[EigResult]) -> Value {
    let by_problem: Vec<Value> = results.iter().map(|r| Value::from(r.stats.matvecs)).collect();
    let matvecs: usize = results.iter().map(|r| r.stats.matvecs).sum();
    let filter_matvecs: usize = results.iter().map(|r| r.stats.filter_matvecs).sum();
    let trisolves: usize = results.iter().map(|r| r.stats.trisolve_count).sum();
    let factor_secs: f64 = results.iter().map(|r| r.stats.factor_secs).sum();
    let total_secs: f64 = results.iter().map(|r| r.stats.secs).sum();
    Value::obj(vec![
        ("total_matvecs", matvecs.into()),
        ("filter_matvecs", filter_matvecs.into()),
        ("trisolve_count", trisolves.into()),
        ("factor_secs", factor_secs.into()),
        ("avg_solve_secs", (total_secs / results.len() as f64).into()),
        ("matvecs_by_problem", Value::Arr(by_problem)),
    ])
}

fn main() {
    let problems = operators::generate(
        OperatorKind::Helmholtz,
        GenOptions {
            grid: GRID,
            ..Default::default()
        },
        N_PROBLEMS,
        SEED,
    );

    let mut extremal = Vec::with_capacity(N_PROBLEMS);
    let mut shifted = Vec::with_capacity(N_PROBLEMS);
    for p in &problems {
        // Extremal path: everything from the bottom through the window.
        let ext = solve(&p.matrix, WINDOW_START + WINDOW, Transform::None);
        // σ in the gap just below the window, from the extremal values.
        let sigma = 0.5 * (ext.values[WINDOW_START - 1] + ext.values[WINDOW_START]);
        let shift = solve(&p.matrix, WINDOW, Transform::ShiftInvert { sigma });
        // Both arms must agree on the window eigenvalues.
        for (got, want) in shift.values.iter().zip(&ext.values[WINDOW_START..]) {
            assert!(
                (got - want).abs() / want.abs().max(1.0) < 1e-6,
                "window disagreement at σ={sigma}: {got} vs {want}"
            );
        }
        extremal.push(ext);
        shifted.push(shift);
    }

    println!(
        "interior window [{WINDOW_START}, {}) of random Helmholtz, grid {GRID}, tol {TOL:.0e}:",
        WINDOW_START + WINDOW
    );
    println!(
        "{:>4} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "prob", "ext_mv", "shift_mv", "trisolves", "factor_ms", "shift_iters"
    );
    for (i, (e, s)) in extremal.iter().zip(&shifted).enumerate() {
        println!(
            "{i:>4} {:>10} {:>10} {:>10} {:>12.2} {:>12}",
            e.stats.matvecs,
            s.stats.matvecs,
            s.stats.trisolve_count,
            1e3 * s.stats.factor_secs,
            s.stats.iterations,
        );
    }
    let ext_total: usize = extremal.iter().map(|r| r.stats.matvecs).sum();
    let shift_total: usize = shifted.iter().map(|r| r.stats.matvecs).sum();
    let trisolves: usize = shifted.iter().map(|r| r.stats.trisolve_count).sum();
    let factor_secs: f64 = shifted.iter().map(|r| r.stats.factor_secs).sum();
    let reduction = 1.0 - shift_total as f64 / ext_total.max(1) as f64;
    println!(
        "TOTAL: op applications extremal {ext_total} / shift-invert {shift_total} \
         ({:+.1}%), {trisolves} triangular sweeps, {:.1} ms factorizing",
        -100.0 * reduction,
        1e3 * factor_secs,
    );

    let doc = Value::obj(vec![
        ("bench", "transform".into()),
        ("version", 1usize.into()),
        ("grid", GRID.into()),
        ("n_problems", N_PROBLEMS.into()),
        ("window_start", WINDOW_START.into()),
        ("window", WINDOW.into()),
        ("tol", TOL.into()),
        ("seed", SEED.into()),
        ("extremal", arm_record(&extremal)),
        ("shift_invert", arm_record(&shifted)),
        (
            "totals",
            Value::obj(vec![
                ("matvecs_extremal", ext_total.into()),
                ("matvecs_shift_invert", shift_total.into()),
                ("matvec_reduction", reduction.into()),
                ("trisolve_count", trisolves.into()),
                ("factor_secs", factor_secs.into()),
            ]),
        ),
    ]);
    let path = "BENCH_transform.json";
    match std::fs::write(path, doc.to_string_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    assert!(
        shift_total as f64 <= 0.60 * ext_total as f64,
        "shift-invert must reach the window in <= 60% of the extremal arm's \
         operator applications (extremal {ext_total}, shift-invert {shift_total}, \
         {:+.1}%)",
        -100.0 * reduction
    );
}
