//! Length+checksum frame layer for append-only chunked manifests.
//!
//! A v3 manifest is a flat sequence of *frame pairs*:
//!
//! ```text
//! <payload bytes, one JSON object per line, ends '\n'>
//! {"crc":"xxxxxxxx","end":N}\n        <- the trailer line
//! ```
//!
//! where `N` is the payload length in bytes (newline included) and
//! `crc` is the lowercase 8-hex CRC-32 ([`super::crc32`]) of exactly
//! those `N` bytes. A frame is valid iff its trailer line parses, `end`
//! matches, and the checksum matches. The first invalid pair marks the
//! *torn tail*: everything before it is trusted, everything from it on
//! is discarded by truncation on resume. Because the trailer is written
//! after the payload and the pair is fsync'd as a unit, a crash at any
//! byte leaves at most one torn frame.
//!
//! This layer is deliberately ignorant of what the payloads mean —
//! framing and integrity here, manifest semantics in
//! [`crate::coordinator::dataset`].

use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom, Write};
use std::path::Path;

use super::crc32::crc32;
use crate::util::error::Result;
use crate::util::json;

/// Appends checksummed frames to a manifest file.
pub struct FrameWriter {
    file: File,
    written: u64,
}

impl FrameWriter {
    /// Create (truncating) a new frame file.
    pub fn create(path: &Path) -> Result<Self> {
        let file = File::create(path)?;
        Ok(Self { file, written: 0 })
    }

    /// Reopen an existing frame file for appending, first truncating it
    /// to `truncate_to` bytes (the last trusted frame boundary from a
    /// torn-tail scan).
    pub fn open_append(path: &Path, truncate_to: u64) -> Result<Self> {
        let file = OpenOptions::new().read(true).write(true).open(path)?;
        file.set_len(truncate_to)?;
        let mut file = file;
        file.seek(SeekFrom::End(0))?;
        Ok(Self {
            file,
            written: truncate_to,
        })
    }

    /// Append one frame pair: the payload followed by its trailer line.
    /// The payload must be newline-terminated and contain no interior
    /// newlines only if its consumer requires line structure — the
    /// frame layer itself checks just the terminator.
    pub fn write_frame(&mut self, payload: &[u8]) -> Result<()> {
        assert!(
            payload.last() == Some(&b'\n'),
            "frame payloads must end with a newline"
        );
        let trailer = format!("{{\"crc\":\"{:08x}\",\"end\":{}}}\n", crc32(payload), payload.len());
        self.file.write_all(payload)?;
        self.file.write_all(trailer.as_bytes())?;
        self.written += payload.len() as u64 + trailer.len() as u64;
        Ok(())
    }

    /// Force written frames to stable storage (`fdatasync`).
    pub fn sync(&mut self) -> Result<()> {
        self.file.sync_data()?;
        Ok(())
    }

    /// Total bytes written through this writer (equals the file length
    /// when created fresh or after `open_append` truncation).
    pub fn written(&self) -> u64 {
        self.written
    }
}

/// Walks the frame pairs of a manifest file, validating each and
/// stopping at the first torn one.
pub struct FrameScanner {
    reader: BufReader<File>,
    payload: Vec<u8>,
    trailer: Vec<u8>,
    /// Byte length of the valid prefix (end of the last good frame).
    valid_bytes: u64,
    /// A torn tail was seen: bytes exist past `valid_bytes` that do not
    /// form a complete valid frame.
    torn: bool,
    file_len: u64,
}

impl FrameScanner {
    /// Open a frame file for scanning.
    pub fn open(path: &Path) -> Result<Self> {
        let file = File::open(path)?;
        let file_len = file.metadata()?.len();
        Ok(Self {
            reader: BufReader::new(file),
            payload: Vec::new(),
            trailer: Vec::new(),
            valid_bytes: 0,
            torn: false,
            file_len,
        })
    }

    /// The next valid frame's payload (borrowed from internal scratch),
    /// or `None` at end of input *or* at a torn tail — check
    /// [`FrameScanner::torn`] to distinguish. Never errors on torn
    /// data; I/O failures only.
    pub fn next_frame(&mut self) -> Result<Option<&[u8]>> {
        if self.torn || self.valid_bytes == self.file_len {
            return Ok(None);
        }
        self.payload.clear();
        self.trailer.clear();
        let got = self.reader.read_until(b'\n', &mut self.payload)?;
        if got == 0 {
            return Ok(None);
        }
        if self.payload.last() != Some(&b'\n') {
            self.torn = true;
            return Ok(None);
        }
        let got_trailer = self.reader.read_until(b'\n', &mut self.trailer)?;
        if got_trailer == 0 || self.trailer.last() != Some(&b'\n') {
            self.torn = true;
            return Ok(None);
        }
        if !Self::trailer_matches(&self.trailer, &self.payload) {
            self.torn = true;
            return Ok(None);
        }
        self.valid_bytes += (self.payload.len() + self.trailer.len()) as u64;
        Ok(Some(&self.payload))
    }

    fn trailer_matches(trailer: &[u8], payload: &[u8]) -> bool {
        let Ok(text) = std::str::from_utf8(trailer) else {
            return false;
        };
        let Ok(v) = json::parse(text) else {
            return false;
        };
        let Some(end) = v.get("end").and_then(|x| x.as_f64()) else {
            return false;
        };
        if end as u64 != payload.len() as u64 {
            return false;
        }
        let Some(crc_hex) = v.get("crc").and_then(|x| x.as_str()) else {
            return false;
        };
        let Ok(want) = u32::from_str_radix(crc_hex, 16) else {
            return false;
        };
        crc32(payload) == want
    }

    /// Bytes of validated prefix so far (a safe truncation point).
    pub fn valid_bytes(&self) -> u64 {
        self.valid_bytes
    }

    /// Whether scanning stopped at invalid/incomplete trailing data.
    pub fn torn(&self) -> bool {
        self.torn
    }

    /// Total length of the underlying file.
    pub fn file_len(&self) -> u64 {
        self.file_len
    }
}

/// Convenience: scan a whole file into owned payloads plus tear state.
/// Used by readers of modest manifests and by tests; the streaming
/// paths drive [`FrameScanner`] directly.
pub fn scan_all(path: &Path) -> Result<(Vec<Vec<u8>>, u64, bool)> {
    let mut scanner = FrameScanner::open(path)?;
    let mut frames = Vec::new();
    while let Some(p) = scanner.next_frame()? {
        frames.push(p.to_vec());
    }
    Ok((frames, scanner.valid_bytes(), scanner.torn()))
}

/// Read the first `len` bytes of a file (tests and tear diagnostics).
pub fn read_prefix(path: &Path, len: u64) -> Result<Vec<u8>> {
    let mut f = File::open(path)?;
    let mut buf = vec![0u8; len as usize];
    f.read_exact(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "scsf_chunk_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_preserves_payloads_in_order() {
        let dir = tmpdir("roundtrip");
        let path = dir.join("frames");
        let payloads: Vec<Vec<u8>> = (0..5)
            .map(|i| format!("{{\"frame\":\"chunk\",\"seq\":{i}}}\n").into_bytes())
            .collect();
        let mut w = FrameWriter::create(&path).unwrap();
        for p in &payloads {
            w.write_frame(p).unwrap();
        }
        w.sync().unwrap();
        let file_len = std::fs::metadata(&path).unwrap().len();
        assert_eq!(w.written(), file_len);

        let (frames, valid, torn) = scan_all(&path).unwrap();
        assert_eq!(frames, payloads);
        assert_eq!(valid, file_len);
        assert!(!torn);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncation_at_every_byte_yields_a_valid_prefix() {
        let dir = tmpdir("trunc");
        let full = dir.join("full");
        let payloads: Vec<Vec<u8>> =
            (0..4).map(|i| format!("{{\"seq\":{i},\"x\":\"abc\"}}\n").into_bytes()).collect();
        let mut w = FrameWriter::create(&full).unwrap();
        let mut boundaries = vec![0u64];
        for p in &payloads {
            w.write_frame(p).unwrap();
            boundaries.push(w.written());
        }
        let bytes = std::fs::read(&full).unwrap();

        for cut in 0..=bytes.len() {
            let path = dir.join("cut");
            std::fs::write(&path, &bytes[..cut]).unwrap();
            let (frames, valid, torn) = scan_all(&path).unwrap();
            // The valid prefix is the largest frame boundary <= cut.
            let want_valid = *boundaries
                .iter()
                .filter(|&&b| b <= cut as u64)
                .max()
                .unwrap();
            assert_eq!(valid, want_valid, "cut at {cut}");
            let want_frames = boundaries.iter().filter(|&&b| b != 0 && b <= cut as u64).count();
            assert_eq!(frames.len(), want_frames, "cut at {cut}");
            assert_eq!(torn, (cut as u64) != want_valid, "cut at {cut}");
            assert_eq!(&frames[..], &payloads[..want_frames], "cut at {cut}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corruption_mid_file_stops_the_scan_there() {
        let dir = tmpdir("corrupt");
        let path = dir.join("frames");
        let mut w = FrameWriter::create(&path).unwrap();
        let p0 = b"{\"seq\":0}\n".to_vec();
        let p1 = b"{\"seq\":1}\n".to_vec();
        w.write_frame(&p0).unwrap();
        let boundary = w.written();
        w.write_frame(&p1).unwrap();
        drop(w);
        // Flip one payload byte of the second frame.
        let mut bytes = std::fs::read(&path).unwrap();
        let idx = boundary as usize + 2;
        bytes[idx] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();

        let (frames, valid, torn) = scan_all(&path).unwrap();
        assert_eq!(frames, vec![p0]);
        assert_eq!(valid, boundary);
        assert!(torn);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn open_append_truncates_then_extends() {
        let dir = tmpdir("append");
        let path = dir.join("frames");
        let mut w = FrameWriter::create(&path).unwrap();
        w.write_frame(b"{\"seq\":0}\n").unwrap();
        let boundary = w.written();
        w.write_frame(b"{\"seq\":1}\n").unwrap();
        drop(w);
        // Tear the second frame, then resume from the first boundary.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..boundary as usize + 3]).unwrap();

        let mut w = FrameWriter::open_append(&path, boundary).unwrap();
        w.write_frame(b"{\"seq\":1,\"retry\":true}\n").unwrap();
        w.sync().unwrap();

        let (frames, _, torn) = scan_all(&path).unwrap();
        assert!(!torn);
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0], b"{\"seq\":0}\n");
        assert_eq!(frames[1], b"{\"seq\":1,\"retry\":true}\n");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
